//! [`RunSpec`] — the crate's one validated description of a run
//! (DESIGN.md §12).
//!
//! A `RunSpec` unifies what used to live in four parallel config structs:
//! dataset selection and protocol parameters ([`ExperimentSpec`]), execution
//! mode/path and backend choice, an optional scenario timeline, deployment
//! parameters ([`Target::Deploy`]), and sweep axes ([`SweepAxes`]).  It is
//! bidirectional with the INI layer — [`RunSpec::from_ini`] and
//! [`RunSpec::to_ini`] round-trip — so config files, CLI flags, and
//! programmatic use share one schema with one validation pass
//! ([`RunSpec::build`]).

use crate::api::error::GolfError;
use crate::api::session::Session;
use crate::config::{ini, BackendChoice, DeploySpec, ExperimentSpec};
use crate::data::dataset::Dataset;
use crate::gossip::create_model::Variant;
use crate::gossip::protocol::ExecPath;
use crate::learning::MergeMode;
use crate::p2p::overlay::SamplerConfig;
use crate::scenario::Scenario;

/// Which execution substrate runs the protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Target {
    /// The event-driven simulator (`backend = event | event-pjrt`): faithful
    /// per-message timing, jitter, churn — the paper's semantics.
    #[default]
    Sim,
    /// The cycle-synchronous batched engine
    /// (`backend = batched-native | batched-pjrt`): maximally vectorized,
    /// timing quantized to whole cycles.
    Batched,
    /// The real localhost-TCP deployment runtime (`[deploy]` section).
    Deploy,
}

impl Target {
    pub fn name(&self) -> &'static str {
        match self {
            Target::Sim => "sim",
            Target::Batched => "batched",
            Target::Deploy => "deploy",
        }
    }

    /// The target a backend choice implies (deployment is orthogonal to the
    /// backend key and selected by [`RunSpec::deploy`] / a `[deploy]`
    /// section instead).
    pub fn for_backend(backend: BackendChoice) -> Target {
        match backend {
            BackendChoice::Event | BackendChoice::EventPjrt => Target::Sim,
            BackendChoice::BatchedNative | BackendChoice::BatchedPjrt => Target::Batched,
        }
    }
}

/// The grid axes of a parameter sweep over the three Table-I datasets
/// (`[sweep]` INI section).  Scale, cycles, seed, eval peers, and execution
/// mode/path come from the embedded experiment; the axes below are crossed
/// with the dataset registry exactly as [`crate::experiments::sweep::run_grid`]
/// does.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepAxes {
    pub variants: Vec<Variant>,
    /// `false` = no failures, `true` = Section VI-A(i) "all failures"
    pub failures: Vec<bool>,
    /// scripted scenario axis; `"none"` is the baseline cell
    pub scenarios: Vec<String>,
    /// gossip graph axis (DESIGN.md §16); `"complete"` is the baseline cell
    pub topologies: Vec<String>,
    pub replicates: u64,
    pub threads: usize,
}

impl Default for SweepAxes {
    fn default() -> Self {
        SweepAxes {
            variants: vec![Variant::Rw, Variant::Mu],
            failures: vec![false, true],
            scenarios: vec!["none".into()],
            topologies: vec!["complete".into()],
            replicates: 1,
            threads: crate::experiments::sweep::thread_count(),
        }
    }
}

impl SweepAxes {
    fn from_section(kv: &ini::Section) -> Result<Self, GolfError> {
        let mut axes = SweepAxes::default();
        for (k, v) in kv {
            match k.as_str() {
                "variants" => {
                    axes.variants = v
                        .split(',')
                        .map(|s| {
                            Variant::parse(s.trim())
                                .ok_or_else(|| GolfError::config(format!("bad variant {s:?}")))
                        })
                        .collect::<Result<_, _>>()?;
                }
                "failures" => {
                    axes.failures = v
                        .split(',')
                        .map(|s| match s.trim() {
                            "none" => Ok(false),
                            "extreme" => Ok(true),
                            other => {
                                Err(GolfError::config(format!("bad failures {other:?}")))
                            }
                        })
                        .collect::<Result<_, _>>()?;
                }
                "scenarios" => {
                    axes.scenarios = v.split(',').map(|s| s.trim().to_string()).collect();
                }
                "topologies" => {
                    axes.topologies = v.split(',').map(|s| s.trim().to_string()).collect();
                }
                "replicates" => {
                    axes.replicates = v.parse().map_err(|_| {
                        GolfError::config(format!("bad replicates {v:?}"))
                    })?;
                }
                "threads" => {
                    axes.threads = v
                        .parse()
                        .map_err(|_| GolfError::config(format!("bad threads {v:?}")))?;
                }
                other => {
                    return Err(GolfError::config(format!("[sweep]: unknown key {other:?}")))
                }
            }
        }
        Ok(axes)
    }

    fn to_ini_section(&self) -> String {
        let variants: Vec<&str> = self.variants.iter().map(|v| v.name()).collect();
        let failures: Vec<&str> = self
            .failures
            .iter()
            .map(|&f| if f { "extreme" } else { "none" })
            .collect();
        format!(
            "[sweep]\nvariants = {}\nfailures = {}\nscenarios = {}\ntopologies = {}\nreplicates = {}\nthreads = {}\n",
            variants.join(","),
            failures.join(","),
            self.scenarios.join(","),
            self.topologies.join(","),
            self.replicates,
            self.threads
        )
    }
}

/// The single front door: a validating description of one run (or one sweep
/// grid) over any execution target.
///
/// ```
/// use golf::api::{NullObserver, RunSpec};
///
/// # fn main() -> Result<(), golf::api::GolfError> {
/// let session = RunSpec::new("urls")
///     .scale(0.005)          // 50 nodes — a smoke-test sized network
///     .cycles(3)
///     .eval_peers(5)
///     .build()?;             // one validation pass, dataset built
/// let outcome = session.run(&mut NullObserver)?;
/// assert_eq!(outcome.curve().unwrap().points.len(), 3);
/// # Ok(())
/// # }
/// ```
///
/// The same schema round-trips through the INI layer:
///
/// ```
/// use golf::api::{RunSpec, Target};
///
/// # fn main() -> Result<(), golf::api::GolfError> {
/// let spec = RunSpec::from_ini("[experiment]\ndataset = spambase\ncycles = 9\n")?;
/// assert_eq!(spec.experiment.cycles, 9);
/// assert_eq!(spec.target, Target::Sim);
/// assert_eq!(RunSpec::from_ini(&spec.to_ini())?, spec);
/// # Ok(())
/// # }
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    /// dataset selection, protocol parameters, backend, exec mode/path,
    /// scenario — the shared schema of every target
    pub experiment: ExperimentSpec,
    pub target: Target,
    /// wall-clock gossip period Δ in milliseconds ([`Target::Deploy`] only)
    pub delta_ms: u64,
    /// deployment node count; 0 = one node per training row
    pub nodes: usize,
    /// deployment worker threads multiplexing the nodes; 0 = auto
    pub node_groups: usize,
    /// grid axes; `Some` turns the spec into a sweep over the dataset
    /// registry (requires `target = Sim` on the native event backend)
    pub sweep: Option<SweepAxes>,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec::from_spec(ExperimentSpec::default())
    }
}

impl RunSpec {
    /// A spec for `dataset` with paper-default protocol parameters.
    pub fn new(dataset: &str) -> Self {
        let mut spec = RunSpec::from_spec(ExperimentSpec::default());
        spec.experiment.dataset = dataset.to_string();
        spec
    }

    /// Wrap an [`ExperimentSpec`]; the target follows the backend choice.
    pub fn from_spec(experiment: ExperimentSpec) -> Self {
        RunSpec {
            target: Target::for_backend(experiment.backend),
            experiment,
            delta_ms: DeploySpec::default().delta_ms,
            nodes: 0,
            node_groups: 0,
            sweep: None,
        }
    }

    /// The embedded experiment schema (inverse of [`RunSpec::from_spec`]).
    pub fn to_spec(&self) -> ExperimentSpec {
        self.experiment.clone()
    }

    /// Wrap a [`DeploySpec`] as a [`Target::Deploy`] run.
    pub fn from_deploy_spec(spec: DeploySpec) -> Self {
        RunSpec {
            experiment: spec.experiment,
            target: Target::Deploy,
            delta_ms: spec.delta_ms,
            nodes: spec.nodes,
            node_groups: spec.node_groups,
            sweep: None,
        }
    }

    /// The deployment view of this spec (inverse of
    /// [`RunSpec::from_deploy_spec`]).
    pub fn to_deploy_spec(&self) -> DeploySpec {
        DeploySpec {
            experiment: self.experiment.clone(),
            delta_ms: self.delta_ms,
            nodes: self.nodes,
            node_groups: self.node_groups,
        }
    }

    // ---- chainable builder surface -------------------------------------

    pub fn scale(mut self, scale: f64) -> Self {
        self.experiment.scale = scale;
        self
    }

    pub fn cycles(mut self, cycles: u64) -> Self {
        self.experiment.cycles = cycles;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.experiment.seed = seed;
        self
    }

    pub fn variant(mut self, variant: Variant) -> Self {
        self.experiment.variant = variant;
        self
    }

    /// Select the learner by name (`pegasos` | `adaline` | `logreg` |
    /// `pairwise-auc`); validated at [`RunSpec::build`].
    pub fn learner(mut self, name: &str) -> Self {
        self.experiment.learner_name = name.to_string();
        self
    }

    pub fn lambda(mut self, lambda: f32) -> Self {
        self.experiment.lambda = lambda;
        self
    }

    /// MERGE rule for the Mu/Um variants: coordinate averaging (the paper's
    /// Algorithm 3) or the sign-agreement quorum vote (DESIGN.md §17).
    pub fn merge(mut self, mode: MergeMode) -> Self {
        self.experiment.merge = mode;
        self
    }

    /// Example-reservoir capacity K for the pairwise learner (ignored by
    /// pointwise learners); bounds validated at [`RunSpec::build`].
    pub fn reservoir(mut self, k: usize) -> Self {
        self.experiment.reservoir = k;
        self
    }

    pub fn cache(mut self, cache: usize) -> Self {
        self.experiment.cache = cache;
        self
    }

    pub fn sampler(mut self, sampler: SamplerConfig) -> Self {
        self.experiment.sampler = sampler;
        self
    }

    /// Enable the Section VI-A(i) "all failures" setup (50% drop, [Δ,10Δ]
    /// delay, churn).
    pub fn failures(mut self, on: bool) -> Self {
        self.experiment.failures = on;
        self
    }

    pub fn voting(mut self, on: bool) -> Self {
        self.experiment.voting = on;
        self
    }

    pub fn similarity(mut self, on: bool) -> Self {
        self.experiment.similarity = on;
        self
    }

    pub fn eval_peers(mut self, n: usize) -> Self {
        self.experiment.eval_peers = n;
        self
    }

    /// Pick the compute backend; the target follows (event backends run the
    /// event-driven simulator, batched backends the cycle-synchronous
    /// driver) unless the spec is a deployment.
    pub fn backend(mut self, backend: BackendChoice) -> Self {
        self.experiment.backend = backend;
        if self.target != Target::Deploy {
            self.target = Target::for_backend(backend);
        }
        self
    }

    /// Debug/parity stepping: one engine call per delivery.
    pub fn scalar_mode(mut self) -> Self {
        self.experiment.mode = "scalar".into();
        self
    }

    /// Micro-batch coalescing window in ticks (0 = exact-timestamp).
    pub fn coalesce(mut self, ticks: u64) -> Self {
        self.experiment.mode = "microbatch".into();
        self.experiment.coalesce = ticks;
        self
    }

    pub fn exec(mut self, path: ExecPath) -> Self {
        self.experiment.exec_path = path;
        self
    }

    /// Shard the event-driven simulator into `n` contiguous node ranges
    /// (DESIGN.md §13).  `n ≥ 2` leases worker threads from the process-wide
    /// budget and requires the native event backend; results are bit-for-bit
    /// independent of `n`.
    pub fn shards(mut self, n: usize) -> Self {
        self.experiment.shards = n;
        self
    }

    /// Constrain gossip to a graph topology (DESIGN.md §16): `ring:K`,
    /// `grid`, `kreg:K`, `ba:M`, `graph:<file>`, `graph-inline:a-b,…`,
    /// optionally prefixed `allow-disconnected:`.  `"complete"` / `"none"`
    /// clear the constraint (the paper's implicit all-pairs overlay).
    pub fn topology(mut self, spec: &str) -> Result<Self, GolfError> {
        self.experiment.topology =
            crate::p2p::TopologySpec::parse(spec).map_err(GolfError::config)?;
        Ok(self)
    }

    /// Attach a scenario timeline.
    pub fn scenario(mut self, scenario: Scenario) -> Self {
        self.experiment.scenario = Some(scenario);
        self
    }

    /// Attach a built-in scenario by name (`golf scenario --list`).
    pub fn builtin_scenario(mut self, name: &str) -> Result<Self, GolfError> {
        self.experiment.scenario = Some(crate::scenario::builtin(name)?);
        Ok(self)
    }

    /// Turn the spec into a real localhost-TCP deployment: wall-clock Δ in
    /// milliseconds and the node count (0 = one node per training row).
    pub fn deploy(mut self, delta_ms: u64, nodes: usize) -> Self {
        self.target = Target::Deploy;
        self.delta_ms = delta_ms;
        self.nodes = nodes;
        self
    }

    /// Worker threads multiplexing a deployment's nodes (0 = auto: the
    /// thread-ledger budget).  Each group hosts at most
    /// `net::deploy::MAX_GROUP_NODES` nodes, so this also raises the
    /// deployable node-count bound.
    pub fn node_groups(mut self, groups: usize) -> Self {
        self.node_groups = groups;
        self
    }

    /// Turn the spec into a grid sweep over the dataset registry.
    pub fn sweep(mut self, axes: SweepAxes) -> Self {
        self.sweep = Some(axes);
        self
    }

    // ---- INI bidirectionality ------------------------------------------

    /// Parse the full schema from INI text: `[experiment]` (plus embedded
    /// scenario sections), an optional `[deploy]` section (which selects
    /// [`Target::Deploy`]), and an optional `[sweep]` section.  Unknown
    /// sections are rejected — one schema, one validation pass.
    pub fn from_ini(text: &str) -> Result<Self, GolfError> {
        let doc = ini::parse(text)?;
        for section in doc.keys() {
            let known = matches!(section.as_str(), "experiment" | "deploy" | "sweep" | "scenario")
                || section.starts_with("phase.")
                || section.starts_with("event.");
            if !known && !(section.is_empty() && doc[section].is_empty()) {
                if section.is_empty() {
                    return Err(GolfError::config(
                        "top-level keys outside a section (expected [experiment], \
                         [deploy], [sweep], or scenario sections)"
                            .to_string(),
                    ));
                }
                return Err(GolfError::config(format!("unknown section [{section}]")));
            }
        }
        let mut experiment = ExperimentSpec::default();
        if let Some(kv) = doc.get("experiment") {
            experiment.apply(kv)?;
        }
        if crate::config::has_scenario_sections(&doc) {
            experiment.scenario = Some(Scenario::from_ini_doc(&doc)?);
        }
        let mut spec = if let Some(kv) = doc.get("deploy") {
            let mut d = DeploySpec { experiment, ..Default::default() };
            for (k, v) in kv {
                // strict: only deployment keys belong in [deploy]
                if !d.apply_deploy_key(k, v)? {
                    return Err(GolfError::config(format!("[deploy]: unknown key {k:?}")));
                }
            }
            RunSpec::from_deploy_spec(d)
        } else {
            RunSpec::from_spec(experiment)
        };
        if let Some(kv) = doc.get("sweep") {
            spec.sweep = Some(SweepAxes::from_section(kv)?);
        }
        Ok(spec)
    }

    /// Read and parse a config file.
    pub fn from_ini_file(path: &str) -> Result<Self, GolfError> {
        let text =
            std::fs::read_to_string(path).map_err(|e| GolfError::io(path.to_string(), e))?;
        Self::from_ini(&text)
    }

    /// Serialize the full schema back to INI text.  `from_ini(to_ini(s))`
    /// reconstructs an equal spec: every `[experiment]`/`[deploy]`/`[sweep]`
    /// key is emitted explicitly, and an attached scenario is written either
    /// as a `scenario = <builtin>` reference (when it is exactly a built-in)
    /// or as embedded `[scenario]`/`[phase.*]`/`[event.*]` sections.  One
    /// caveat inherits from the INI grammar: scenario/phase/event names and
    /// summaries containing the comment/section characters `;`, `#`, `[`,
    /// `]` are sanitized on emission (see [`Scenario::to_ini_sections`]),
    /// so such programmatically built names round-trip to their sanitized
    /// form.
    pub fn to_ini(&self) -> String {
        let e = &self.experiment;
        let mut out = String::from("[experiment]\n");
        let mut kv = |k: &str, v: String| {
            out.push_str(k);
            out.push_str(" = ");
            out.push_str(&v);
            out.push('\n');
        };
        kv("dataset", e.dataset.clone());
        kv("scale", e.scale.to_string());
        kv("cycles", e.cycles.to_string());
        kv("variant", e.variant.name().to_string());
        kv("learner", e.learner_name.clone());
        kv("lambda", e.lambda.to_string());
        kv("eta", e.eta.to_string());
        kv("merge", e.merge.name().to_string());
        kv("reservoir", e.reservoir.to_string());
        kv("cache", e.cache.to_string());
        kv("sampler", e.sampler.name().to_string());
        if let SamplerConfig::Newscast { view_size } = e.sampler {
            kv("view", view_size.to_string());
        }
        kv("failures", if e.failures { "extreme" } else { "none" }.to_string());
        kv("seed", e.seed.to_string());
        kv("eval_peers", e.eval_peers.to_string());
        kv("voting", e.voting.to_string());
        kv("similarity", e.similarity.to_string());
        kv("backend", e.backend.name().to_string());
        kv("mode", e.mode.clone());
        kv("coalesce", e.coalesce.to_string());
        kv("exec", e.exec_path.name().to_string());
        kv("shards", e.shards.to_string());
        if let Some(t) = &e.topology {
            kv("topology", t.name());
        }
        // a scenario that is exactly a built-in round-trips by name; any
        // other timeline embeds as full sections
        let mut scenario_sections = None;
        if let Some(s) = &e.scenario {
            match crate::scenario::builtin(&s.name) {
                Ok(b) if &b == s => kv("scenario", s.name.clone()),
                _ => scenario_sections = Some(s.to_ini_sections()),
            }
        }
        if self.target == Target::Deploy {
            out.push_str(&format!(
                "\n[deploy]\ndelta_ms = {}\nnodes = {}\nnode_groups = {}\n",
                self.delta_ms, self.nodes, self.node_groups
            ));
        }
        if let Some(axes) = &self.sweep {
            out.push('\n');
            out.push_str(&axes.to_ini_section());
        }
        if let Some(sections) = scenario_sections {
            out.push('\n');
            out.push_str(&sections);
        }
        out
    }

    // ---- validation and session construction ---------------------------

    /// Dataset-independent validation: learner/mode well-formed, the
    /// backend matches the target, sweep axes are usable.  [`RunSpec::build`]
    /// runs this plus the dataset-dependent checks.
    pub fn validate(&self) -> Result<(), GolfError> {
        self.experiment.learner()?;
        self.experiment.exec_mode()?;
        // pairwise/quorum cross-key rules (reservoir bounds, matching,
        // batched target) — shared with protocol_config/deploy_config
        self.experiment.validate_learning()?;
        if self.experiment.shards == 0 {
            return Err(GolfError::config("shards must be at least 1".to_string()));
        }
        if self.experiment.shards >= 2 {
            if self.target != Target::Sim || self.experiment.backend != BackendChoice::Event {
                return Err(GolfError::config(format!(
                    "sharded execution (shards = {}) runs on the native \
                     event-driven simulator (target sim, backend event); got \
                     target {} on backend {}",
                    self.experiment.shards,
                    self.target.name(),
                    self.experiment.backend.name()
                )));
            }
            if self.experiment.sampler == SamplerConfig::Matching {
                return Err(GolfError::config(
                    "sampler = matching needs a globally consistent partner \
                     table and only runs with shards = 1"
                        .to_string(),
                ));
            }
        }
        if self.experiment.topology.is_some() {
            if self.experiment.sampler == SamplerConfig::Matching {
                return Err(GolfError::config(
                    "sampler = matching ignores graph constraints; \
                     drop `topology =` or pick oracle/newscast"
                        .to_string(),
                ));
            }
            if self.target == Target::Batched {
                return Err(GolfError::config(
                    "topology requires the event-driven simulator or \
                     deployment (the batched driver has no per-message \
                     peer sampling to constrain)"
                        .to_string(),
                ));
            }
        }
        match self.target {
            Target::Sim => {
                if !matches!(
                    self.experiment.backend,
                    BackendChoice::Event | BackendChoice::EventPjrt
                ) {
                    return Err(GolfError::config(format!(
                        "target sim needs an event backend, got {:?}",
                        self.experiment.backend.name()
                    )));
                }
            }
            Target::Batched => {
                if !matches!(
                    self.experiment.backend,
                    BackendChoice::BatchedNative | BackendChoice::BatchedPjrt
                ) {
                    return Err(GolfError::config(format!(
                        "target batched needs a batched backend, got {:?}",
                        self.experiment.backend.name()
                    )));
                }
                if self.experiment.voting || self.experiment.similarity {
                    return Err(GolfError::config(
                        "voting/similarity measurement needs the event-driven \
                         simulator (they would be silently ignored by the \
                         batched driver)"
                            .to_string(),
                    ));
                }
            }
            Target::Deploy => {
                if self.experiment.backend != BackendChoice::Event {
                    return Err(GolfError::config(format!(
                        "the deployment runtime executes the protocol natively \
                         inside each node thread; backend {} does not apply \
                         under target deploy",
                        self.experiment.backend.name()
                    )));
                }
                if self.experiment.voting || self.experiment.similarity {
                    return Err(GolfError::config(
                        "voting/similarity measurement needs the event-driven \
                         simulator (the deployment evaluates freshest models \
                         only)"
                            .to_string(),
                    ));
                }
            }
        }
        if let Some(axes) = &self.sweep {
            if self.target != Target::Sim || self.experiment.backend != BackendChoice::Event {
                return Err(GolfError::config(format!(
                    "sweep axes run on the native event-driven simulator \
                     (target sim, backend event); got target {} on backend {}",
                    self.target.name(),
                    self.experiment.backend.name()
                )));
            }
            if self.experiment.scenario.is_some() {
                return Err(GolfError::config(
                    "a sweep takes its scenario axis from `[sweep] scenarios = \
                     <built-in names>`; an attached scenario timeline would be \
                     silently ignored by the grid"
                        .to_string(),
                ));
            }
            if self.experiment.voting || self.experiment.similarity {
                return Err(GolfError::config(
                    "voting/similarity measurement is not available on the \
                     sweep grid"
                        .to_string(),
                ));
            }
            // the grid consumes scale/cycles/seed/eval_peers/mode/coalesce/
            // exec from the experiment; every other per-run key is fixed by
            // the grid itself (3-dataset registry, per-dataset pegasos λ,
            // paper cache/sampler, variants and failure modes from the
            // axes) and must not be silently dropped
            let d = ExperimentSpec::default();
            let e = &self.experiment;
            // any registry dataset is fine as a starting point (the grid
            // always runs all three); a non-registry name is a real override
            let dataset_in_registry =
                matches!(e.dataset.as_str(), "reuters" | "spambase" | "urls");
            let overridden = [
                ("dataset", !dataset_in_registry),
                ("variant", e.variant != d.variant),
                ("learner", e.learner_name != d.learner_name),
                ("lambda", e.lambda != d.lambda),
                ("eta", e.eta != d.eta),
                ("merge", e.merge != d.merge),
                ("reservoir", e.reservoir != d.reservoir),
                ("cache", e.cache != d.cache),
                ("sampler", e.sampler != d.sampler),
                ("failures", e.failures != d.failures),
                ("topology", e.topology != d.topology),
            ];
            if let Some((key, _)) = overridden.iter().find(|(_, changed)| *changed) {
                return Err(GolfError::config(format!(
                    "sweep: `{key}` is fixed by the grid (the 3-dataset \
                     registry runs pegasos with per-dataset λ; variants and \
                     failure modes come from the [sweep] axes) — remove it \
                     or use `golf run`"
                )));
            }
            if axes.variants.is_empty()
                || axes.failures.is_empty()
                || axes.scenarios.is_empty()
                || axes.topologies.is_empty()
            {
                return Err(GolfError::config(
                    "sweep axes must be non-empty (variants, failures, \
                     scenarios, topologies)"
                        .to_string(),
                ));
            }
            if axes.replicates == 0 {
                return Err(GolfError::config("sweep needs replicates >= 1".to_string()));
            }
            for name in &axes.scenarios {
                if name != "none" {
                    // full per-dataset timeline validation happens in
                    // run_grid; resolve the name up front
                    crate::scenario::builtin(name)?;
                }
            }
            for t in &axes.topologies {
                // graph construction (over each dataset's node count)
                // happens in run_grid; reject malformed specs up front
                crate::p2p::TopologySpec::parse(t).map_err(GolfError::config)?;
            }
        }
        Ok(())
    }

    /// One validation pass, then build the dataset and return a runnable
    /// [`Session`].  Sweep specs validate their axes here and build their
    /// datasets lazily inside the grid runner.
    pub fn build(self) -> Result<Session<'static>, GolfError> {
        self.validate()?;
        Session::create_owned(self)
    }

    /// Like [`RunSpec::build`], but run against an already-built dataset
    /// (the experiment drivers share one dataset across many runs; the
    /// dataset's generation seed need not equal the protocol seed).  The
    /// dataset's name must match `experiment.dataset`.
    pub fn build_with(self, data: &Dataset) -> Result<Session<'_>, GolfError> {
        self.validate()?;
        Session::create_borrowed(self, data)
    }
}
