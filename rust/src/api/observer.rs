//! [`Observer`] — typed progress events streamed from a running
//! [`crate::api::Session`] (DESIGN.md §12).
//!
//! All three drivers (event-driven simulator, cycle-synchronous batched
//! engine, socket deployment) emit the same [`RunEvent`] stream while they
//! execute: gossip-cycle boundaries, convergence-curve points as they are
//! measured, scenario mutations as they are applied, and per-node accounting
//! (deployment).  Observation is strictly passive — no observer call touches
//! RNG or protocol state, so an observed run is bit-for-bit identical to an
//! unobserved one (pinned in tests/api.rs).
//!
//! Three implementations are provided: [`NullObserver`] (discard),
//! [`ProgressObserver`] (live stderr lines, used by the `golf` CLI), and
//! [`CurveRecorder`] (capture for tests, dashboards, early stopping).

use crate::eval::tracker::EvalPoint;

/// One typed progress event of a running session.
#[derive(Clone, Debug)]
pub enum RunEvent {
    /// A gossip-cycle boundary was crossed.  The event-driven simulator
    /// emits every integer boundary its event stream passes; the batched
    /// driver emits every cycle; the deployment emits measurement cycles.
    Cycle { cycle: u64 },
    /// One measured convergence-curve point, exactly as it lands in the
    /// returned [`crate::api::Outcome`]'s curve.
    Eval { point: EvalPoint },
    /// A scenario mutation was applied at a cycle boundary.
    Scenario { cycle: u64, mutation: String },
    /// Per-node accounting (deployment: one event per node at shutdown).
    NodeStats { node: usize, sent: u64, received: u64, bytes_sent: u64 },
}

/// Receives the [`RunEvent`] stream of a session.  Implementations must be
/// cheap and side-effect-free with respect to the run itself.
pub trait Observer {
    fn on_event(&mut self, event: &RunEvent);
}

/// Discards every event (the default for headless runs).
#[derive(Clone, Copy, Debug, Default)]
pub struct NullObserver;

impl Observer for NullObserver {
    fn on_event(&mut self, _event: &RunEvent) {}
}

/// Streams progress to stderr as the run executes — the `golf` CLI's live
/// output.  Cycle boundaries are silent (too chatty); eval points, scenario
/// mutations, and node stats print one line each.
#[derive(Clone, Copy, Debug, Default)]
pub struct ProgressObserver {
    /// also print per-node stats lines (deployment runs)
    pub verbose_nodes: bool,
}

impl ProgressObserver {
    pub fn stderr() -> Self {
        ProgressObserver { verbose_nodes: false }
    }
}

impl Observer for ProgressObserver {
    fn on_event(&mut self, event: &RunEvent) {
        match event {
            RunEvent::Cycle { .. } => {}
            RunEvent::Eval { point: p } => {
                let vote = p
                    .err_vote
                    .map_or(String::new(), |v| format!("  vote {v:.4}"));
                let sim = p
                    .similarity
                    .map_or(String::new(), |s| format!("  sim {s:.4}"));
                eprintln!(
                    "cycle {:>6}  err {:.4} ±{:.4}{vote}{sim}  (msgs {})",
                    p.cycle, p.err_mean, p.err_std, p.messages_sent
                );
            }
            RunEvent::Scenario { cycle, mutation } => {
                eprintln!("scenario @ cycle {cycle}: {mutation}");
            }
            RunEvent::NodeStats { node, sent, received, bytes_sent } => {
                if self.verbose_nodes {
                    eprintln!(
                        "node {node:>4}: sent {sent} received {received} bytes {bytes_sent}"
                    );
                }
            }
        }
    }
}

/// Records the full event stream (and the eval points in order) for later
/// inspection — the hook tests and dashboards build on.
#[derive(Clone, Debug, Default)]
pub struct CurveRecorder {
    pub events: Vec<RunEvent>,
}

impl CurveRecorder {
    pub fn new() -> Self {
        CurveRecorder::default()
    }

    /// The eval points observed so far, in emission order.
    pub fn eval_points(&self) -> Vec<&EvalPoint> {
        self.events
            .iter()
            .filter_map(|e| match e {
                RunEvent::Eval { point } => Some(point),
                _ => None,
            })
            .collect()
    }

    /// The cycle boundaries observed so far.
    pub fn cycles(&self) -> Vec<u64> {
        self.events
            .iter()
            .filter_map(|e| match e {
                RunEvent::Cycle { cycle } => Some(*cycle),
                _ => None,
            })
            .collect()
    }

    /// `(cycle, description)` of every scenario mutation observed so far.
    pub fn mutations(&self) -> Vec<(u64, &str)> {
        self.events
            .iter()
            .filter_map(|e| match e {
                RunEvent::Scenario { cycle, mutation } => Some((*cycle, mutation.as_str())),
                _ => None,
            })
            .collect()
    }

    /// `(node, sent, received)` of every node-stats event observed so far.
    pub fn node_stats(&self) -> Vec<(usize, u64, u64)> {
        self.events
            .iter()
            .filter_map(|e| match e {
                RunEvent::NodeStats { node, sent, received, .. } => {
                    Some((*node, *sent, *received))
                }
                _ => None,
            })
            .collect()
    }
}

impl Observer for CurveRecorder {
    fn on_event(&mut self, event: &RunEvent) {
        self.events.push(event.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::tracker::point_from_errors;

    #[test]
    fn recorder_filters_by_event_kind() {
        let mut r = CurveRecorder::new();
        r.on_event(&RunEvent::Cycle { cycle: 1 });
        r.on_event(&RunEvent::Eval { point: point_from_errors(1, &[0.5], None, None, None, 10) });
        r.on_event(&RunEvent::Scenario { cycle: 1, mutation: "drop -> 0.5".into() });
        r.on_event(&RunEvent::NodeStats { node: 3, sent: 7, received: 6, bytes_sent: 99 });
        assert_eq!(r.cycles(), vec![1]);
        assert_eq!(r.eval_points().len(), 1);
        assert_eq!(r.eval_points()[0].messages_sent, 10);
        assert_eq!(r.mutations(), vec![(1, "drop -> 0.5")]);
        assert_eq!(r.node_stats(), vec![(3, 7, 6)]);
        // the null observer accepts everything silently
        let mut n = NullObserver;
        for e in &r.events {
            n.on_event(e);
        }
    }
}
