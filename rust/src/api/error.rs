//! [`GolfError`] — the crate's single typed error surface (DESIGN.md §12).
//!
//! Before the facade every layer grew its own error convention: `config/`,
//! `cli.rs` and `experiments/sweep.rs` returned `Result<_, String>`, the
//! coordinator returned `io::Error`, the engines `anyhow::Error`, and only
//! the scenario layer had a typed error.  `GolfError` unifies them: one enum,
//! one `Display`, source chaining where a typed source exists, and a stable
//! [`GolfError::exit_code`] mapping so `golf` CLI failures are scriptable.

use crate::net::wire::WireError;
use crate::scenario::ScenarioError;
use std::fmt;

/// Typed error for everything the public [`crate::api`] surface can reject.
///
/// Each variant maps to a distinct process exit code in the `golf` binary
/// (see [`GolfError::exit_code`]), so scripts can tell a bad flag (2) from a
/// missing dataset (3) from a filesystem failure (4) without parsing stderr.
#[derive(Debug)]
pub enum GolfError {
    /// Invalid configuration: unknown key, bad value, duplicate CLI flag,
    /// or an inconsistent [`crate::api::RunSpec`] combination.
    Config(String),
    /// Dataset selection or dataset/topology mismatch (unknown dataset
    /// name, more deployment nodes than training rows, too few nodes).
    Data(String),
    /// Scenario parse or validation failure (typed source preserved, plus
    /// optional "which scenario / which dataset" context).
    Scenario { context: String, source: ScenarioError },
    /// Compute-backend construction or execution failure (e.g. missing
    /// PJRT artifacts, engine step errors).
    Backend(String),
    /// Filesystem or socket I/O failure, with the path/operation context.
    Io { context: String, source: std::io::Error },
    /// Wire-format encode/decode failure (typed source preserved).
    Wire(WireError),
}

impl GolfError {
    pub fn config(msg: impl Into<String>) -> Self {
        GolfError::Config(msg.into())
    }

    pub fn data(msg: impl Into<String>) -> Self {
        GolfError::Data(msg.into())
    }

    pub fn backend(msg: impl Into<String>) -> Self {
        GolfError::Backend(msg.into())
    }

    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        GolfError::Io { context: context.into(), source }
    }

    /// A scenario error with "which scenario / which dataset" context.
    pub fn scenario_in(context: impl Into<String>, source: ScenarioError) -> Self {
        GolfError::Scenario { context: context.into(), source }
    }

    /// The process exit code the `golf` binary uses for this variant.
    /// Pinned by test: 0 is success, 1 is reserved (legacy catch-all), and
    /// each variant gets its own code so failures are scriptable.
    pub fn exit_code(&self) -> i32 {
        match self {
            GolfError::Config(_) => 2,
            GolfError::Data(_) => 3,
            GolfError::Io { .. } => 4,
            GolfError::Scenario { .. } => 5,
            GolfError::Backend(_) => 6,
            GolfError::Wire(_) => 7,
        }
    }

    /// Short machine-readable variant name (error tables, telemetry).
    pub fn kind(&self) -> &'static str {
        match self {
            GolfError::Config(_) => "config",
            GolfError::Data(_) => "data",
            GolfError::Scenario { .. } => "scenario",
            GolfError::Backend(_) => "backend",
            GolfError::Io { .. } => "io",
            GolfError::Wire(_) => "wire",
        }
    }
}

impl fmt::Display for GolfError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GolfError::Config(m) => write!(f, "{m}"),
            GolfError::Data(m) => write!(f, "{m}"),
            GolfError::Scenario { context, source } => {
                if context.is_empty() {
                    write!(f, "{source}")
                } else {
                    write!(f, "{context}: {source}")
                }
            }
            GolfError::Backend(m) => write!(f, "backend: {m}"),
            GolfError::Io { context, source } => {
                if context.is_empty() {
                    write!(f, "{source}")
                } else {
                    write!(f, "{context}: {source}")
                }
            }
            GolfError::Wire(e) => write!(f, "wire: {e}"),
        }
    }
}

impl std::error::Error for GolfError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            GolfError::Scenario { source, .. } => Some(source),
            GolfError::Io { source, .. } => Some(source),
            GolfError::Wire(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ScenarioError> for GolfError {
    fn from(e: ScenarioError) -> Self {
        GolfError::Scenario { context: String::new(), source: e }
    }
}

impl From<std::io::Error> for GolfError {
    fn from(e: std::io::Error) -> Self {
        GolfError::Io { context: String::new(), source: e }
    }
}

impl From<WireError> for GolfError {
    fn from(e: WireError) -> Self {
        GolfError::Wire(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The CLI contract: one stable exit code per variant (satellite pin).
    #[test]
    fn exit_codes_are_distinct_and_stable() {
        let io = GolfError::io("x", std::io::Error::new(std::io::ErrorKind::Other, "y"));
        let cases: Vec<(GolfError, i32)> = vec![
            (GolfError::config("bad flag"), 2),
            (GolfError::data("no such dataset"), 3),
            (io, 4),
            (
                GolfError::from(ScenarioError::UnknownBuiltin { name: "x".into() }),
                5,
            ),
            (GolfError::backend("no artifacts"), 6),
            (GolfError::Wire(WireError::Truncated), 7),
        ];
        let mut seen = std::collections::HashSet::new();
        for (e, code) in &cases {
            assert_eq!(e.exit_code(), *code, "{}", e.kind());
            assert!(*code > 1, "codes 0/1 are reserved");
            assert!(seen.insert(*code), "duplicate exit code {code}");
        }
    }

    #[test]
    fn display_and_source_chain() {
        let e = GolfError::from(ScenarioError::UnknownBuiltin { name: "warp".into() });
        assert!(e.to_string().contains("warp"));
        assert!(std::error::Error::source(&e).is_some());
        // contextful scenario errors name the failing pairing
        let e = GolfError::scenario_in(
            "scenario \"x\" on reuters",
            ScenarioError::UnknownBuiltin { name: "x".into() },
        );
        assert!(e.to_string().starts_with("scenario \"x\" on reuters: "), "{e}");
        assert_eq!(e.exit_code(), 5);
        let e = GolfError::io(
            "config.ini",
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        );
        assert!(e.to_string().starts_with("config.ini: "));
        assert!(std::error::Error::source(&e).is_some());
        let e = GolfError::config("bad value");
        assert!(std::error::Error::source(&e).is_none());
    }
}
