//! Shared experiment plumbing: the Table-I dataset registry with calibrated
//! hyperparameters, standard run lengths, and output locations.

use crate::data::dataset::Dataset;
use crate::data::synthetic::{reuters_like, spambase_like, urls_like, Scale};
use std::path::PathBuf;

/// One benchmark dataset plus its experiment parameters.
pub struct ExpDataset {
    pub ds: Dataset,
    /// Pegasos λ (calibrated per dataset; the paper does not report λ)
    pub lambda: f32,
    /// run length in gossip cycles for figure-style experiments
    pub cycles: u64,
    /// paper's Table-I Pegasos-20k reference error
    pub paper_error: f64,
}

/// The three Table-I datasets at `scale` (1.0 = full size).
pub fn datasets(seed: u64, scale: f64) -> Vec<ExpDataset> {
    vec![
        ExpDataset {
            ds: reuters_like(seed, Scale(scale)),
            lambda: 1e-2,
            cycles: 1000,
            paper_error: 0.025,
        },
        ExpDataset {
            ds: spambase_like(seed, Scale(scale)),
            lambda: 1e-2,
            cycles: 1000,
            paper_error: 0.111,
        },
        ExpDataset {
            ds: urls_like(seed, Scale(scale)),
            lambda: 1e-2,
            cycles: 1000,
            paper_error: 0.080,
        },
    ]
}

/// Scale knob for quick runs: `GOLF_SCALE` env var (default 1.0, figures) —
/// integration tests and smoke benches set e.g. 0.05.
pub fn env_scale() -> f64 {
    std::env::var("GOLF_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Cycle-count scale: `GOLF_CYCLES` caps the run length.
pub fn env_cycles(default: u64) -> u64 {
    std::env::var("GOLF_CYCLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Where experiment CSVs land.
pub fn results_dir() -> PathBuf {
    std::env::var_os("GOLF_RESULTS")
        .map(Into::into)
        .unwrap_or_else(|| PathBuf::from("results"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_three_calibrated_sets() {
        let sets = datasets(1, 0.01);
        assert_eq!(sets.len(), 3);
        let names: Vec<&str> = sets.iter().map(|e| e.ds.name.as_str()).collect();
        assert_eq!(names, vec!["reuters", "spambase", "urls"]);
        for e in &sets {
            assert!(e.lambda > 0.0);
            assert!(e.paper_error > 0.0 && e.paper_error < 0.5);
        }
    }

    #[test]
    fn env_knobs_default() {
        // do not set env in tests (they run in parallel) — just defaults
        assert!(env_scale() > 0.0);
        assert_eq!(env_cycles(123), 123);
    }
}
