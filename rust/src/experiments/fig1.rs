//! Figure 1: prediction error vs. gossip cycle (log x), without failures
//! (upper row) and under the extreme failure scenario (lower row), for
//! the sequential Pegasos, P2PegasosRW, P2PegasosMU, WB1 and WB2.
//!
//! All curves of the figure are independent simulation runs; they execute in
//! parallel through the [`sweep`] job pool.

use crate::api::{NullObserver, RunSpec};
use crate::baselines::{
    sequential,
    weighted_bagging::{self, Bagging},
};
use crate::config::ExperimentSpec;
use crate::eval::tracker::Curve;
use crate::experiments::common::ExpDataset;
use crate::experiments::sweep;
use crate::gossip::create_model::Variant;
use crate::learning::Learner;

pub struct Fig1Panel {
    pub dataset: String,
    pub failures: bool,
    pub curves: Vec<Curve>,
}

/// The gossip runs of the figure go through the `api::RunSpec` facade, one
/// spec per curve, against the shared pre-built dataset.
fn gossip_spec(
    e: &ExpDataset,
    variant: Variant,
    cycles: u64,
    failures: bool,
    seed: u64,
) -> ExperimentSpec {
    ExperimentSpec {
        dataset: e.ds.name.clone(),
        cycles,
        variant,
        lambda: e.lambda,
        seed,
        failures,
        ..Default::default()
    }
}

type CurveJob<'a> = Box<dyn Fn() -> Curve + Sync + 'a>;

/// The five independent runs of one panel, as parallelizable jobs (curve
/// order: pegasos, wb1, wb2, p2pegasos-rw, p2pegasos-mu).
fn curve_jobs<'a>(
    e: &'a ExpDataset,
    cycles: u64,
    failures: bool,
    seed: u64,
) -> Vec<CurveJob<'a>> {
    let learner = Learner::pegasos(e.lambda);
    let mut jobs: Vec<CurveJob<'a>> = Vec::new();

    // baselines are failure-free references in both rows (they model ideal
    // central resources, not the P2P network)
    jobs.push(Box::new(move || {
        let mut c = sequential::curve(&e.ds, &learner, cycles, seed);
        c.label = "pegasos".into();
        c
    }));
    jobs.push(Box::new(move || {
        let mut c = weighted_bagging::curve(&e.ds, &learner, Bagging::Wb1, wb_cycles(cycles), seed);
        c.label = "wb1".into();
        c
    }));
    jobs.push(Box::new(move || {
        let mut c = weighted_bagging::curve(&e.ds, &learner, Bagging::Wb2, wb_cycles(cycles), seed);
        c.label = "wb2".into();
        c
    }));
    for variant in [Variant::Rw, Variant::Mu] {
        jobs.push(Box::new(move || {
            let outcome = RunSpec::from_spec(gossip_spec(e, variant, cycles, failures, seed))
                .build_with(&e.ds)
                .expect("figure spec is valid")
                .run(&mut NullObserver)
                .expect("native event-driven run");
            let mut c = outcome.into_run().expect("sim outcome").curve;
            c.label = format!("p2pegasos-{}", variant.name());
            c
        }));
    }
    jobs
}

/// One dataset panel (one column of Fig. 1), runs parallelized.
pub fn panel(e: &ExpDataset, cycles: u64, failures: bool, seed: u64) -> Fig1Panel {
    let curves = sweep::run_jobs(curve_jobs(e, cycles, failures, seed), sweep::thread_count());
    Fig1Panel { dataset: e.ds.name.clone(), failures, curves }
}

/// WB baselines update all N models per cycle — cap the horizon to keep the
/// cost of the ideal baselines in check (they converge by ~100 cycles).
fn wb_cycles(cycles: u64) -> u64 {
    cycles.min(200)
}

/// Run the full figure: every dataset x {no failure, all failures}.
pub fn run_figure(sets: &[ExpDataset], cycles_override: Option<u64>, seed: u64) -> Vec<Fig1Panel> {
    run_figure_threads(sets, cycles_override, seed, sweep::thread_count())
}

/// Same, with an explicit worker count: every curve of every panel is one job
/// in a single flat pool.
pub fn run_figure_threads(
    sets: &[ExpDataset],
    cycles_override: Option<u64>,
    seed: u64,
    threads: usize,
) -> Vec<Fig1Panel> {
    let mut groups: Vec<((String, bool), Vec<CurveJob>)> = Vec::new();
    for e in sets {
        let cycles = cycles_override.unwrap_or(e.cycles);
        for failures in [false, true] {
            groups.push(((e.ds.name.clone(), failures), curve_jobs(e, cycles, failures, seed)));
        }
    }
    sweep::run_grouped(groups, threads)
        .into_iter()
        .map(|((dataset, failures), curves)| Fig1Panel { dataset, failures, curves })
        .collect()
}

/// Convergence-ordering summary used by tests and the bench report: cycles
/// to reach `threshold` error for each curve of a panel.
pub fn cycles_to_threshold(panel: &Fig1Panel, threshold: f64) -> Vec<(String, Option<u64>)> {
    panel
        .curves
        .iter()
        .map(|c| (c.label.clone(), c.cycles_to_reach(threshold)))
        .collect()
}

pub fn to_csv(panels: &[Fig1Panel], dir: &std::path::Path) -> std::io::Result<()> {
    for p in panels {
        let f = dir.join(format!(
            "fig1_{}_{}.csv",
            p.dataset,
            if p.failures { "af" } else { "nofail" }
        ));
        crate::eval::csv::write_curves(&f, &p.curves)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::datasets;

    #[test]
    fn panel_produces_all_curves_and_ordering() {
        let sets = datasets(3, 0.02);
        let urls = &sets[2];
        let p = panel(urls, 60, false, 9);
        assert_eq!(p.curves.len(), 5);
        let labels: Vec<&str> = p.curves.iter().map(|c| c.label.as_str()).collect();
        assert!(labels.contains(&"pegasos"));
        assert!(labels.contains(&"wb1"));
        assert!(labels.contains(&"p2pegasos-mu"));
        // headline shape: merging speeds up convergence — the MU curve's
        // mean error over the log grid must not exceed the RW curve's
        // (area-under-curve comparison is robust to single-point noise)
        let auc = |l: &str| {
            let c = p.curves.iter().find(|c| c.label == l).unwrap();
            c.points.iter().map(|pt| pt.err_mean).sum::<f64>() / c.points.len() as f64
        };
        assert!(
            auc("p2pegasos-mu") <= auc("p2pegasos-rw") + 0.02,
            "mu auc {} vs rw auc {}",
            auc("p2pegasos-mu"),
            auc("p2pegasos-rw")
        );
    }

    #[test]
    fn parallel_figure_matches_serial() {
        let sets = datasets(4, 0.01);
        let serial = run_figure_threads(&sets[2..3], Some(8), 5, 1);
        let parallel = run_figure_threads(&sets[2..3], Some(8), 5, 4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.dataset, b.dataset);
            assert_eq!(a.failures, b.failures);
            assert_eq!(a.curves.len(), b.curves.len());
            for (ca, cb) in a.curves.iter().zip(&b.curves) {
                assert_eq!(ca.label, cb.label);
                let ea: Vec<f64> = ca.points.iter().map(|p| p.err_mean).collect();
                let eb: Vec<f64> = cb.points.iter().map(|p| p.err_mean).collect();
                assert_eq!(ea, eb, "thread count changed curve {}", ca.label);
            }
        }
    }

    #[test]
    fn csv_written_per_panel() {
        let sets = datasets(4, 0.01);
        let p = panel(&sets[2], 10, false, 1);
        let dir = std::env::temp_dir().join("golf_fig1_test");
        to_csv(&[p], &dir).unwrap();
        assert!(dir.join("fig1_urls_nofail.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
