//! Figure 2: P2PegasosMU vs P2PegasosUM vs PERFECT MATCHING — prediction
//! error (upper row) and mean pairwise cosine model similarity (lower row),
//! failure-free.  Runs execute in parallel through the [`sweep`] job pool.

use crate::api::{NullObserver, RunSpec};
use crate::baselines::perfect_matching::run_perfect_matching;
use crate::config::ExperimentSpec;
use crate::eval::tracker::Curve;
use crate::experiments::common::ExpDataset;
use crate::experiments::sweep;
use crate::gossip::create_model::Variant;
use crate::gossip::protocol::ProtocolConfig;
use crate::learning::Learner;

pub struct Fig2Panel {
    pub dataset: String,
    pub curves: Vec<Curve>,
}

/// The facade spec of one gossip curve (similarity measurement on).
fn spec(e: &ExpDataset, variant: Variant, cycles: u64, seed: u64) -> ExperimentSpec {
    ExperimentSpec {
        dataset: e.ds.name.clone(),
        cycles,
        variant,
        lambda: e.lambda,
        similarity: true,
        seed,
        ..Default::default()
    }
}

/// The PERFECT MATCHING baseline keeps its dedicated driver; this is its
/// protocol configuration (same parameters as [`spec`]).
fn matching_cfg(e: &ExpDataset, cycles: u64, seed: u64) -> ProtocolConfig {
    let mut cfg = ProtocolConfig::paper_default(cycles);
    cfg.variant = Variant::Mu;
    cfg.learner = Learner::pegasos(e.lambda);
    cfg.eval.similarity = true;
    cfg.seed = seed;
    cfg
}

type CurveJob<'a> = Box<dyn Fn() -> Curve + Sync + 'a>;

/// Curve order: p2pegasos-mu, p2pegasos-um, p2pegasos-mu-matching.
fn curve_jobs<'a>(e: &'a ExpDataset, cycles: u64, seed: u64) -> Vec<CurveJob<'a>> {
    let mut jobs: Vec<CurveJob<'a>> = Vec::new();
    for variant in [Variant::Mu, Variant::Um] {
        jobs.push(Box::new(move || {
            let outcome = RunSpec::from_spec(spec(e, variant, cycles, seed))
                .build_with(&e.ds)
                .expect("figure spec is valid")
                .run(&mut NullObserver)
                .expect("native event-driven run");
            let mut c = outcome.into_run().expect("sim outcome").curve;
            c.label = format!("p2pegasos-{}", variant.name());
            c
        }));
    }
    jobs.push(Box::new(move || {
        let res = run_perfect_matching(matching_cfg(e, cycles, seed), &e.ds);
        let mut c = res.curve;
        c.label = "p2pegasos-mu-matching".into();
        c
    }));
    jobs
}

pub fn panel(e: &ExpDataset, cycles: u64, seed: u64) -> Fig2Panel {
    let curves = sweep::run_jobs(curve_jobs(e, cycles, seed), sweep::thread_count());
    Fig2Panel { dataset: e.ds.name.clone(), curves }
}

pub fn run_figure(sets: &[ExpDataset], cycles_override: Option<u64>, seed: u64) -> Vec<Fig2Panel> {
    run_figure_threads(sets, cycles_override, seed, sweep::thread_count())
}

pub fn run_figure_threads(
    sets: &[ExpDataset],
    cycles_override: Option<u64>,
    seed: u64,
    threads: usize,
) -> Vec<Fig2Panel> {
    let groups: Vec<(String, Vec<CurveJob>)> = sets
        .iter()
        .map(|e| (e.ds.name.clone(), curve_jobs(e, cycles_override.unwrap_or(e.cycles), seed)))
        .collect();
    sweep::run_grouped(groups, threads)
        .into_iter()
        .map(|(dataset, curves)| Fig2Panel { dataset, curves })
        .collect()
}

pub fn to_csv(panels: &[Fig2Panel], dir: &std::path::Path) -> std::io::Result<()> {
    for p in panels {
        crate::eval::csv::write_curves(&dir.join(format!("fig2_{}.csv", p.dataset)), &p.curves)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::datasets;

    #[test]
    fn panel_has_similarity_curves() {
        let sets = datasets(5, 0.02);
        let p = panel(&sets[2], 30, 3);
        assert_eq!(p.curves.len(), 3);
        for c in &p.curves {
            assert!(c.points.iter().all(|pt| pt.similarity.is_some()));
        }
        // similarity should rise as models converge toward each other
        let mu = &p.curves[0];
        let first = mu.points.first().unwrap().similarity.unwrap();
        let last = mu.points.last().unwrap().similarity.unwrap();
        assert!(last > first, "similarity should increase: {first} -> {last}");
    }
}
