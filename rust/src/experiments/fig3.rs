//! Figure 3: the effect of local voting (Algorithm 4, cache size 10) for
//! P2PegasosRW and P2PegasosMU, without failures (upper row) and under the
//! extreme failure scenario (lower row).  Curves carry both the
//! freshest-model error (err_mean) and the voted error (err_vote).
//! Runs execute in parallel through the [`sweep`] job pool.

use crate::api::{NullObserver, RunSpec};
use crate::config::ExperimentSpec;
use crate::eval::tracker::Curve;
use crate::experiments::common::ExpDataset;
use crate::experiments::sweep;
use crate::gossip::create_model::Variant;

pub struct Fig3Panel {
    pub dataset: String,
    pub failures: bool,
    pub curves: Vec<Curve>,
}

type CurveJob<'a> = Box<dyn Fn() -> Curve + Sync + 'a>;

/// Curve order: p2pegasos-rw, p2pegasos-mu.
fn curve_jobs<'a>(
    e: &'a ExpDataset,
    cycles: u64,
    failures: bool,
    cache_size: usize,
    seed: u64,
) -> Vec<CurveJob<'a>> {
    [Variant::Rw, Variant::Mu]
        .into_iter()
        .map(|variant| -> CurveJob<'a> {
            Box::new(move || {
                let spec = ExperimentSpec {
                    dataset: e.ds.name.clone(),
                    cycles,
                    variant,
                    lambda: e.lambda,
                    cache: cache_size,
                    voting: true,
                    seed,
                    failures,
                    ..Default::default()
                };
                let outcome = RunSpec::from_spec(spec)
                    .build_with(&e.ds)
                    .expect("figure spec is valid")
                    .run(&mut NullObserver)
                    .expect("native event-driven run");
                let mut c = outcome.into_run().expect("sim outcome").curve;
                c.label = format!("p2pegasos-{}", variant.name());
                c
            })
        })
        .collect()
}

pub fn panel(
    e: &ExpDataset,
    cycles: u64,
    failures: bool,
    cache_size: usize,
    seed: u64,
) -> Fig3Panel {
    let curves = sweep::run_jobs(
        curve_jobs(e, cycles, failures, cache_size, seed),
        sweep::thread_count(),
    );
    Fig3Panel { dataset: e.ds.name.clone(), failures, curves }
}

pub fn run_figure(sets: &[ExpDataset], cycles_override: Option<u64>, seed: u64) -> Vec<Fig3Panel> {
    run_figure_threads(sets, cycles_override, seed, sweep::thread_count())
}

pub fn run_figure_threads(
    sets: &[ExpDataset],
    cycles_override: Option<u64>,
    seed: u64,
    threads: usize,
) -> Vec<Fig3Panel> {
    let mut groups: Vec<((String, bool), Vec<CurveJob>)> = Vec::new();
    for e in sets {
        let cycles = cycles_override.unwrap_or(e.cycles);
        for failures in [false, true] {
            groups.push(((e.ds.name.clone(), failures), curve_jobs(e, cycles, failures, 10, seed)));
        }
    }
    sweep::run_grouped(groups, threads)
        .into_iter()
        .map(|((dataset, failures), curves)| Fig3Panel { dataset, failures, curves })
        .collect()
}

/// Cache-size ablation (beyond the paper; DESIGN.md §8), one parallel run per
/// cache size.
pub fn cache_sweep(e: &ExpDataset, cycles: u64, sizes: &[usize], seed: u64) -> Vec<(usize, Curve)> {
    let curves = sweep::run_indexed(sizes.len(), sweep::thread_count(), |i| {
        let p = panel_serial(e, cycles, false, sizes[i], seed);
        p.curves.into_iter().nth(1).unwrap() // MU curve
    });
    sizes.iter().copied().zip(curves).collect()
}

/// Serial panel used inside already-parallel jobs (avoids nested pools).
fn panel_serial(
    e: &ExpDataset,
    cycles: u64,
    failures: bool,
    cache_size: usize,
    seed: u64,
) -> Fig3Panel {
    let curves = sweep::run_jobs(curve_jobs(e, cycles, failures, cache_size, seed), 1);
    Fig3Panel { dataset: e.ds.name.clone(), failures, curves }
}

pub fn to_csv(panels: &[Fig3Panel], dir: &std::path::Path) -> std::io::Result<()> {
    for p in panels {
        let f = dir.join(format!(
            "fig3_{}_{}.csv",
            p.dataset,
            if p.failures { "af" } else { "nofail" }
        ));
        crate::eval::csv::write_curves(&f, &p.curves)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::datasets;

    #[test]
    fn voting_fields_present_and_voting_helps_rw() {
        let sets = datasets(6, 0.02);
        let p = panel(&sets[2], 40, false, 10, 4);
        let rw = &p.curves[0];
        assert!(rw.points.iter().all(|pt| pt.err_vote.is_some()));
        // paper: voting clearly helps the no-merge RW variant (compare at
        // the last point; allow noise slack)
        let last = rw.points.last().unwrap();
        assert!(
            last.err_vote.unwrap() <= last.err_mean + 0.05,
            "vote {} vs freshest {}",
            last.err_vote.unwrap(),
            last.err_mean
        );
    }

    #[test]
    fn cache_sweep_runs() {
        let sets = datasets(7, 0.01);
        let sw = cache_sweep(&sets[2], 10, &[1, 5, 10], 2);
        assert_eq!(sw.len(), 3);
        assert_eq!(sw[0].0, 1);
    }
}
