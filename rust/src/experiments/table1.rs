//! Table I: dataset statistics and the sequential Pegasos baseline error
//! after 20,000 iterations.  The per-dataset baselines are independent and
//! run in parallel through the [`sweep`] job pool.

use crate::baselines::sequential;
use crate::experiments::common::ExpDataset;
use crate::experiments::sweep;

#[derive(Debug)]
pub struct Table1Row {
    pub name: String,
    pub n_train: usize,
    pub n_test: usize,
    pub d: usize,
    pub pos: usize,
    pub neg: usize,
    pub pegasos_20k: f64,
    pub paper_pegasos_20k: f64,
}

pub fn run(sets: &[ExpDataset], seed: u64) -> Vec<Table1Row> {
    run_threads(sets, seed, sweep::thread_count())
}

pub fn run_threads(sets: &[ExpDataset], seed: u64, threads: usize) -> Vec<Table1Row> {
    sweep::run_indexed(sets.len(), threads, |i| {
        let e = &sets[i];
        let (pos, neg) = e.ds.class_counts();
        Table1Row {
            name: e.ds.name.clone(),
            n_train: e.ds.n_train(),
            n_test: e.ds.n_test(),
            d: e.ds.d(),
            pos,
            neg,
            pegasos_20k: sequential::pegasos_20k_error(&e.ds, e.lambda, seed),
            paper_pegasos_20k: e.paper_error,
        }
    })
}

pub fn print(rows: &[Table1Row]) {
    let mut t = crate::util::benchkit::Table::new(&[
        "dataset",
        "train",
        "test",
        "features",
        "class ratio",
        "Pegasos 20k (ours)",
        "(paper)",
    ]);
    for r in rows {
        t.row(&[
            r.name.clone(),
            r.n_train.to_string(),
            r.n_test.to_string(),
            r.d.to_string(),
            format!("{}:{}", r.pos, r.neg),
            format!("{:.3}", r.pegasos_20k),
            format!("{:.3}", r.paper_pegasos_20k),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::datasets;

    #[test]
    fn rows_carry_stats() {
        let sets = datasets(1, 0.02);
        let rows = run(&sets, 7);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.pegasos_20k >= 0.0 && r.pegasos_20k <= 1.0);
            assert_eq!(r.pos + r.neg, r.n_train);
        }
        print(&rows); // must not panic
    }
}
