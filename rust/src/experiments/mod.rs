//! Experiment drivers regenerating every table and figure of the paper's
//! evaluation (Section VI).  Each bench target (`rust/benches/`) is a thin
//! wrapper over these functions; DESIGN.md §3 is the index.
pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod table1;

pub use common::{datasets, ExpDataset};
