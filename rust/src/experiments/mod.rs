//! Experiment drivers regenerating every table and figure of the paper's
//! evaluation (Section VI).  Each bench target (`rust/benches/`) is a thin
//! wrapper over these functions; DESIGN.md §3 is the index.
//!
//! All drivers fan their independent simulation runs across threads through
//! [`sweep`], with deterministic per-run seeds — parallel and serial
//! execution produce identical curves.
pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig_topology;
pub mod sweep;
pub mod table1;

pub use common::{datasets, ExpDataset};
pub use sweep::{run_grid, SweepCell, SweepConfig};
