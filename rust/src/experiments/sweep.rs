//! Parallel experiment sweep runner (DESIGN.md §3).
//!
//! Every paper experiment decomposes into independent simulation runs — one
//! per (dataset × variant × failure scenario × seed replicate) cell — so the
//! natural scaling axis for the experiment layer is fanning those runs across
//! threads.  This module provides:
//!
//! * [`run_indexed`] / [`run_jobs`] — a deterministic work-stealing job pool
//!   on `std::thread::scope` (the offline crate set has no rayon).  Results
//!   land in submission order regardless of thread interleaving, so parallel
//!   and serial execution produce bit-identical output vectors.
//! * [`run_grid`] — the Table-I grid sweep: each cell's seed is derived
//!   deterministically from the base seed and the cell's identity
//!   ([`crate::util::rng::derive_seed`]), never from execution order.
//!
//! fig1/fig2/fig3/table1 and the CLI all route their runs through this pool.

use crate::api::{GolfError, NullObserver, RunSpec};
use crate::config::ExperimentSpec;
use crate::eval::tracker::Curve;
use crate::experiments::common::datasets;
use crate::gossip::create_model::Variant;
use crate::gossip::protocol::{ExecMode, ExecPath, RunStats};
use crate::util::rng::derive_seed;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count: the process-wide thread budget (`--threads` override, then
/// `GOLF_THREADS`, then the machine's available parallelism).
pub fn thread_count() -> usize {
    crate::util::threads::budget()
}

/// Run `f(0..n)` across up to `threads` workers; `results[i] == f(i)` in
/// submission order.  Jobs are claimed from a shared atomic counter (cheap
/// work stealing); panics in jobs propagate to the caller via the scope.
///
/// Worker threads beyond the caller's own are leased from the process-wide
/// ledger ([`crate::util::threads`]), so a sweep composed with the sharded
/// simulator never oversubscribes the budget; a drained pool degrades to
/// serial execution with identical results.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let lease = crate::util::threads::lease(threads - 1);
    let threads = 1 + lease.granted();
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                results.lock().unwrap()[i] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("every job runs exactly once"))
        .collect()
}

/// Run a list of heterogeneous jobs (boxed closures) through the pool,
/// preserving submission order.
pub fn run_jobs<'a, T: Send>(
    jobs: Vec<Box<dyn Fn() -> T + Sync + 'a>>,
    threads: usize,
) -> Vec<T> {
    let n = jobs.len();
    run_indexed(n, threads, |i| (jobs[i])())
}

/// Run groups of jobs through one flat pool and reassemble the results per
/// group (figure drivers: one group per panel, every curve one job).
pub fn run_grouped<'a, M, T: Send>(
    groups: Vec<(M, Vec<Box<dyn Fn() -> T + Sync + 'a>>)>,
    threads: usize,
) -> Vec<(M, Vec<T>)> {
    let mut meta = Vec::with_capacity(groups.len());
    let mut jobs = Vec::new();
    for (m, j) in groups {
        meta.push((m, j.len()));
        jobs.extend(j);
    }
    let mut results = run_jobs(jobs, threads).into_iter();
    meta.into_iter()
        .map(|(m, k)| (m, results.by_ref().take(k).collect()))
        .collect()
}

/// One sweep grid: the three Table-I datasets crossed with CREATEMODEL
/// variants, failure scenarios, scripted scenario timelines, and seed
/// replicates.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// dataset size multiplier (1.0 = Table-I sizes)
    pub scale: f64,
    /// run length in gossip cycles
    pub cycles: u64,
    pub variants: Vec<Variant>,
    /// failure scenarios: `false` = no failures, `true` = Section VI-A(i)
    /// "all failures"
    pub failures: Vec<bool>,
    /// scripted scenario axis: built-in timeline names, with `"none"` as
    /// the baseline cell (DESIGN.md §11).  Timelines must fit `cycles`.
    pub scenarios: Vec<String>,
    /// gossip graph axis: topology spec strings (DESIGN.md §16), with
    /// `"complete"` as the baseline cell
    pub topologies: Vec<String>,
    /// independent repetitions per cell
    pub replicates: u64,
    pub base_seed: u64,
    pub eval_peers: usize,
    pub exec: ExecMode,
    /// dense vs. O(nnz) sparse kernel dispatch (auto = density-based)
    pub path: ExecPath,
    pub threads: usize,
}

impl SweepConfig {
    /// The paper's Section-VI grid shape: RW + MU, with and without the
    /// extreme failure scenario, one replicate, no scripted timelines.
    pub fn paper_grid(scale: f64, cycles: u64, base_seed: u64) -> Self {
        SweepConfig {
            scale,
            cycles,
            variants: vec![Variant::Rw, Variant::Mu],
            failures: vec![false, true],
            scenarios: vec!["none".into()],
            topologies: vec!["complete".into()],
            replicates: 1,
            base_seed,
            eval_peers: 100,
            exec: ExecMode::default(),
            path: ExecPath::default(),
            threads: thread_count(),
        }
    }
}

/// One completed cell of a sweep grid.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub dataset: String,
    pub variant: Variant,
    pub failures: bool,
    /// scripted scenario name ("none" = baseline)
    pub scenario: String,
    /// topology spec string ("complete" = baseline)
    pub topology: String,
    pub replicate: u64,
    /// the derived per-run seed actually used
    pub seed: u64,
    pub curve: Curve,
    pub stats: RunStats,
}

/// Deterministic per-cell seed: independent of job scheduling and thread
/// count.  Baseline cells keep their historical tag format — scenario-free
/// cells the pre-scenario tag, complete-graph cells the pre-topology tag —
/// so sweep seeds from earlier releases stay reproducible.
pub fn cell_seed(
    base: u64,
    dataset: &str,
    variant: Variant,
    failures: bool,
    scenario: &str,
    topology: &str,
    replicate: u64,
) -> u64 {
    let mut tag = if scenario == "none" {
        format!("{dataset}/{}/{failures}", variant.name())
    } else {
        format!("{dataset}/{}/{failures}/{scenario}", variant.name())
    };
    if topology != "complete" {
        tag.push_str(&format!("/t={topology}"));
    }
    tag.push_str(&format!("/r{replicate}"));
    derive_seed(base, &tag)
}

/// Run the full grid in parallel.  Cells are returned in deterministic
/// (dataset, variant, failures, scenario, topology, replicate) order.  Every cell is
/// constructed through the [`crate::api::RunSpec`] facade (native
/// event-driven simulator), so the grid and a hand-built single run share
/// one configuration path.
///
/// Errors (before any job is dispatched) if a scenario name is not a
/// built-in, or its timeline does not fit `cfg.cycles` or one of the
/// grid's datasets — worker threads never see an invalid timeline.
pub fn run_grid(cfg: &SweepConfig) -> Result<Vec<SweepCell>, GolfError> {
    struct JobDesc {
        ds_idx: usize,
        variant: Variant,
        failures: bool,
        scenario: usize,
        topology: usize,
        replicate: u64,
    }

    // resolve the scenario axis once; every cell clones its timeline
    let scenarios: Vec<(String, Option<crate::scenario::Scenario>)> = cfg
        .scenarios
        .iter()
        .map(|name| {
            let s = if name == "none" {
                None
            } else {
                Some(crate::scenario::builtin(name)?)
            };
            Ok((name.clone(), s))
        })
        .collect::<Result<_, GolfError>>()?;

    // resolve the topology axis once; every cell clones its parsed spec
    let topologies: Vec<(String, Option<crate::p2p::TopologySpec>)> = cfg
        .topologies
        .iter()
        .map(|name| {
            Ok((
                name.clone(),
                crate::p2p::TopologySpec::parse(name).map_err(GolfError::config)?,
            ))
        })
        .collect::<Result<_, GolfError>>()?;

    let sets = datasets(cfg.base_seed, cfg.scale);
    // everything the per-cell RunSpec::build_with validates must hold
    // before dispatch — worker threads never see an invalid cell
    for e in &sets {
        if e.ds.n_train() < 2 {
            return Err(GolfError::data(format!(
                "{} has {} training rows at scale {}; a gossip network needs \
                 at least 2 nodes",
                e.ds.name,
                e.ds.n_train(),
                cfg.scale
            )));
        }
    }
    // every (scenario × dataset) pairing must fit before any run starts
    for (name, s) in &scenarios {
        if let Some(s) = s {
            for e in &sets {
                s.validate(e.ds.n_train(), cfg.cycles).map_err(|err| {
                    GolfError::scenario_in(
                        format!("scenario {name:?} on {}", e.ds.name),
                        err,
                    )
                })?;
            }
        }
    }
    // every (topology × dataset) graph must build, and every scenario with
    // edge events must have a graph to mutate.  Structure checks (degree-0,
    // connectivity, feasibility) are seed-independent for every generator
    // except a pathological kreg realization, so validating against the
    // base seed catches bad cells before a worker thread would panic on its
    // derived seed.
    for (tname, tspec) in &topologies {
        for e in &sets {
            let topo = match tspec {
                None => None,
                Some(spec) => Some(
                    crate::p2p::Topology::build(spec, e.ds.n_train(), cfg.base_seed)
                        .map_err(|err| {
                            GolfError::config(format!(
                                "topology {tname:?} on {}: {err}",
                                e.ds.name
                            ))
                        })?,
                ),
            };
            for (sname, s) in &scenarios {
                if let Some(s) = s {
                    s.validate_topology(topo.as_ref()).map_err(|err| {
                        GolfError::scenario_in(
                            format!(
                                "scenario {sname:?} with topology {tname:?} on {}",
                                e.ds.name
                            ),
                            err,
                        )
                    })?;
                }
            }
        }
    }
    let mut descs = Vec::new();
    for ds_idx in 0..sets.len() {
        for &variant in &cfg.variants {
            for &failures in &cfg.failures {
                for scenario in 0..scenarios.len() {
                    for topology in 0..topologies.len() {
                        for replicate in 0..cfg.replicates {
                            descs.push(JobDesc {
                                ds_idx,
                                variant,
                                failures,
                                scenario,
                                topology,
                                replicate,
                            });
                        }
                    }
                }
            }
        }
    }

    // exec-mode keys for the per-cell specs (shared by every cell)
    let (mode, coalesce) = match cfg.exec {
        ExecMode::Scalar => ("scalar", 0),
        ExecMode::MicroBatch { coalesce } => ("microbatch", coalesce),
    };

    Ok(run_indexed(descs.len(), cfg.threads, |i| {
        let jd = &descs[i];
        let e = &sets[jd.ds_idx];
        let (scn_name, scn) = &scenarios[jd.scenario];
        let (topo_name, topo) = &topologies[jd.topology];
        let seed = cell_seed(
            cfg.base_seed,
            &e.ds.name,
            jd.variant,
            jd.failures,
            scn_name,
            topo_name,
            jd.replicate,
        );
        let spec = ExperimentSpec {
            dataset: e.ds.name.clone(),
            scale: cfg.scale,
            cycles: cfg.cycles,
            variant: jd.variant,
            learner_name: "pegasos".into(),
            lambda: e.lambda,
            eval_peers: cfg.eval_peers,
            seed,
            mode: mode.into(),
            coalesce,
            exec_path: cfg.path,
            failures: jd.failures,
            scenario: scn.clone(),
            topology: topo.clone(),
            ..Default::default()
        };
        let res = RunSpec::from_spec(spec)
            .build_with(&e.ds)
            .expect("cell spec validated before dispatch")
            .run(&mut NullObserver)
            .expect("native event-driven run")
            .into_run()
            .expect("sim target yields a run result");
        SweepCell {
            dataset: e.ds.name.clone(),
            variant: jd.variant,
            failures: jd.failures,
            scenario: scn_name.clone(),
            topology: topo_name.clone(),
            replicate: jd.replicate,
            seed,
            curve: res.curve,
            stats: res.stats,
        }
    }))
}

/// Write sweep results as CSV, one file per (dataset, failure scenario,
/// scripted scenario, topology).  Baseline groups keep the historical
/// names: scenario-free complete-graph groups write
/// `sweep_<dataset>_<failures>.csv`, exactly as before the scenario and
/// topology axes existed.
pub fn to_csv(cells: &[SweepCell], dir: &std::path::Path) -> std::io::Result<()> {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<(String, bool, String, String), Vec<Curve>> = BTreeMap::new();
    for c in cells {
        let mut curve = c.curve.clone();
        let mut label = format!("p2pegasos-{}", c.variant.name());
        if c.scenario != "none" {
            label.push_str(&format!("-{}", c.scenario));
        }
        if c.topology != "complete" {
            label.push_str(&format!("-{}", c.topology));
        }
        label.push_str(&format!("-r{}", c.replicate));
        curve.label = label;
        groups
            .entry((c.dataset.clone(), c.failures, c.scenario.clone(), c.topology.clone()))
            .or_default()
            .push(curve);
    }
    for ((dataset, failures, scenario, topology), curves) in groups {
        let fail = if failures { "af" } else { "nofail" };
        let mut stem = format!("sweep_{dataset}_{fail}");
        if scenario != "none" {
            stem.push_str(&format!("_{scenario}"));
        }
        if topology != "complete" {
            // spec strings carry ':' and ',' (e.g. "ring:2", inline edge
            // lists) — keep filenames portable
            let safe: String = topology
                .chars()
                .map(|ch| if ch.is_ascii_alphanumeric() || ch == '-' { ch } else { '_' })
                .collect();
            stem.push_str(&format!("_{safe}"));
        }
        let f = dir.join(format!("{stem}.csv"));
        crate::eval::csv::write_curves(&f, &curves)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_indexed_preserves_submission_order() {
        let out = run_indexed(64, 8, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn run_indexed_serial_fallback() {
        assert_eq!(run_indexed(3, 1, |i| i + 1), vec![1, 2, 3]);
        assert!(run_indexed(0, 4, |i| i).is_empty());
    }

    #[test]
    fn run_jobs_heterogeneous_closures() {
        let base = vec![10usize, 20, 30];
        let jobs: Vec<Box<dyn Fn() -> usize + Sync>> = base
            .iter()
            .map(|&v| Box::new(move || v + 1) as Box<dyn Fn() -> usize + Sync>)
            .collect();
        assert_eq!(run_jobs(jobs, 2), vec![11, 21, 31]);
    }

    #[test]
    fn thread_count_positive() {
        assert!(thread_count() >= 1);
    }

    #[test]
    fn run_indexed_respects_drained_thread_budget() {
        // drain the process-wide ledger: run_indexed must degrade toward
        // serial execution (never over-subscribe) with identical results
        let hold = crate::util::threads::lease(usize::MAX / 2);
        let out = run_indexed(16, 8, |i| i * 3);
        drop(hold);
        assert_eq!(out, (0..16).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn grid_enumerates_all_cells_in_order() {
        let mut cfg = SweepConfig::paper_grid(0.01, 3, 7);
        cfg.variants = vec![Variant::Mu];
        cfg.failures = vec![false];
        cfg.replicates = 2;
        cfg.eval_peers = 5;
        cfg.threads = 2;
        let cells = run_grid(&cfg).unwrap();
        assert_eq!(cells.len(), 3 * 2); // 3 datasets x 2 replicates
        assert_eq!(cells[0].dataset, "reuters");
        assert_eq!(cells[0].replicate, 0);
        assert_eq!(cells[1].replicate, 1);
        assert_eq!(cells[2].dataset, "spambase");
        for c in &cells {
            assert!(!c.curve.points.is_empty());
            assert_eq!(c.scenario, "none");
            assert_eq!(c.topology, "complete");
            assert_eq!(
                c.seed,
                cell_seed(
                    7,
                    &c.dataset,
                    c.variant,
                    c.failures,
                    &c.scenario,
                    &c.topology,
                    c.replicate,
                )
            );
        }
        // replicates are genuinely independent runs
        assert_ne!(cells[0].seed, cells[1].seed);
    }

    #[test]
    fn scenario_axis_enumerates_and_derives_distinct_seeds() {
        let mut cfg = SweepConfig::paper_grid(0.01, 8, 5);
        cfg.variants = vec![Variant::Mu];
        cfg.failures = vec![false];
        cfg.scenarios = vec!["none".into(), "paper-fig3".into()];
        cfg.replicates = 1;
        cfg.eval_peers = 5;
        cfg.threads = 2;
        let cells = run_grid(&cfg).unwrap();
        assert_eq!(cells.len(), 3 * 2); // 3 datasets x 2 scenarios
        assert_eq!(cells[0].scenario, "none");
        assert_eq!(cells[1].scenario, "paper-fig3");
        assert_ne!(cells[0].seed, cells[1].seed);
        // the "none" tag is unchanged from the pre-scenario format
        assert_eq!(
            cells[0].seed,
            crate::util::rng::derive_seed(5, "reuters/mu/false/r0")
        );
        // the scripted cell really injected failures
        assert!(cells[1].stats.messages_dropped > 0);
        // unknown names and timelines that cannot fit error up front
        // instead of panicking inside a worker thread
        cfg.scenarios = vec!["warp".into()];
        assert!(run_grid(&cfg).is_err());
        cfg.scenarios = vec!["partition-heal".into()]; // needs >= 120 cycles
        assert!(run_grid(&cfg).is_err(), "8-cycle grid cannot fit a cycle-120 phase");
    }

    #[test]
    fn topology_axis_enumerates_and_derives_distinct_seeds() {
        let mut cfg = SweepConfig::paper_grid(0.01, 3, 9);
        cfg.variants = vec![Variant::Mu];
        cfg.failures = vec![false];
        cfg.topologies = vec!["complete".into(), "ring:2".into()];
        cfg.replicates = 1;
        cfg.eval_peers = 5;
        cfg.threads = 2;
        let cells = run_grid(&cfg).unwrap();
        assert_eq!(cells.len(), 3 * 2); // 3 datasets x 2 topologies
        assert_eq!(cells[0].topology, "complete");
        assert_eq!(cells[1].topology, "ring:2");
        assert_ne!(cells[0].seed, cells[1].seed);
        // the complete-graph tag is unchanged from the pre-topology format
        assert_eq!(
            cells[0].seed,
            crate::util::rng::derive_seed(9, "reuters/mu/false/r0")
        );
        // a graph that cannot build on a grid dataset errors before dispatch
        cfg.topologies = vec!["kreg:100000".into()];
        assert!(run_grid(&cfg).is_err(), "kreg degree exceeds the node count");
        // edge-event scenarios require a graph across the whole axis
        cfg.topologies = vec!["complete".into()];
        cfg.cycles = 200;
        cfg.scenarios = vec!["link-storm".into()];
        assert!(run_grid(&cfg).is_err(), "link-storm needs a topology to mutate");
    }
}
