//! Parallel experiment sweep runner (DESIGN.md §3).
//!
//! Every paper experiment decomposes into independent simulation runs — one
//! per (dataset × variant × failure scenario × seed replicate) cell — so the
//! natural scaling axis for the experiment layer is fanning those runs across
//! threads.  This module provides:
//!
//! * [`run_indexed`] / [`run_jobs`] — a deterministic work-stealing job pool
//!   on `std::thread::scope` (the offline crate set has no rayon).  Results
//!   land in submission order regardless of thread interleaving, so parallel
//!   and serial execution produce bit-identical output vectors.
//! * [`run_grid`] — the Table-I grid sweep: each cell's seed is derived
//!   deterministically from the base seed and the cell's identity
//!   ([`crate::util::rng::derive_seed`]), never from execution order.
//!
//! fig1/fig2/fig3/table1 and the CLI all route their runs through this pool.

use crate::eval::tracker::Curve;
use crate::experiments::common::datasets;
use crate::gossip::create_model::Variant;
use crate::gossip::protocol::{run, ExecMode, ExecPath, ProtocolConfig, RunStats};
use crate::learning::Learner;
use crate::util::rng::derive_seed;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count: `GOLF_THREADS` env override, else the machine's available
/// parallelism.
pub fn thread_count() -> usize {
    std::env::var("GOLF_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .max(1)
}

/// Run `f(0..n)` across `threads` workers; `results[i] == f(i)` in submission
/// order.  Jobs are claimed from a shared atomic counter (cheap work
/// stealing); panics in jobs propagate to the caller via the scope.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                results.lock().unwrap()[i] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("every job runs exactly once"))
        .collect()
}

/// Run a list of heterogeneous jobs (boxed closures) through the pool,
/// preserving submission order.
pub fn run_jobs<'a, T: Send>(
    jobs: Vec<Box<dyn Fn() -> T + Sync + 'a>>,
    threads: usize,
) -> Vec<T> {
    let n = jobs.len();
    run_indexed(n, threads, |i| (jobs[i])())
}

/// Run groups of jobs through one flat pool and reassemble the results per
/// group (figure drivers: one group per panel, every curve one job).
pub fn run_grouped<'a, M, T: Send>(
    groups: Vec<(M, Vec<Box<dyn Fn() -> T + Sync + 'a>>)>,
    threads: usize,
) -> Vec<(M, Vec<T>)> {
    let mut meta = Vec::with_capacity(groups.len());
    let mut jobs = Vec::new();
    for (m, j) in groups {
        meta.push((m, j.len()));
        jobs.extend(j);
    }
    let mut results = run_jobs(jobs, threads).into_iter();
    meta.into_iter()
        .map(|(m, k)| (m, results.by_ref().take(k).collect()))
        .collect()
}

/// One sweep grid: the three Table-I datasets crossed with CREATEMODEL
/// variants, failure scenarios and seed replicates.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// dataset size multiplier (1.0 = Table-I sizes)
    pub scale: f64,
    /// run length in gossip cycles
    pub cycles: u64,
    pub variants: Vec<Variant>,
    /// failure scenarios: `false` = no failures, `true` = Section VI-A(i)
    /// "all failures"
    pub failures: Vec<bool>,
    /// independent repetitions per cell
    pub replicates: u64,
    pub base_seed: u64,
    pub eval_peers: usize,
    pub exec: ExecMode,
    /// dense vs. O(nnz) sparse kernel dispatch (auto = density-based)
    pub path: ExecPath,
    pub threads: usize,
}

impl SweepConfig {
    /// The paper's Section-VI grid shape: RW + MU, with and without the
    /// extreme failure scenario, one replicate.
    pub fn paper_grid(scale: f64, cycles: u64, base_seed: u64) -> Self {
        SweepConfig {
            scale,
            cycles,
            variants: vec![Variant::Rw, Variant::Mu],
            failures: vec![false, true],
            replicates: 1,
            base_seed,
            eval_peers: 100,
            exec: ExecMode::default(),
            path: ExecPath::default(),
            threads: thread_count(),
        }
    }
}

/// One completed cell of a sweep grid.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub dataset: String,
    pub variant: Variant,
    pub failures: bool,
    pub replicate: u64,
    /// the derived per-run seed actually used
    pub seed: u64,
    pub curve: Curve,
    pub stats: RunStats,
}

/// Deterministic per-cell seed: independent of job scheduling and thread
/// count.
pub fn cell_seed(
    base: u64,
    dataset: &str,
    variant: Variant,
    failures: bool,
    replicate: u64,
) -> u64 {
    derive_seed(base, &format!("{dataset}/{}/{failures}/r{replicate}", variant.name()))
}

/// Run the full grid in parallel.  Cells are returned in deterministic
/// (dataset, variant, failures, replicate) order.
pub fn run_grid(cfg: &SweepConfig) -> Vec<SweepCell> {
    struct JobDesc {
        ds_idx: usize,
        variant: Variant,
        failures: bool,
        replicate: u64,
    }

    let sets = datasets(cfg.base_seed, cfg.scale);
    let mut descs = Vec::new();
    for ds_idx in 0..sets.len() {
        for &variant in &cfg.variants {
            for &failures in &cfg.failures {
                for replicate in 0..cfg.replicates {
                    descs.push(JobDesc { ds_idx, variant, failures, replicate });
                }
            }
        }
    }

    run_indexed(descs.len(), cfg.threads, |i| {
        let jd = &descs[i];
        let e = &sets[jd.ds_idx];
        let seed = cell_seed(cfg.base_seed, &e.ds.name, jd.variant, jd.failures, jd.replicate);
        let mut pc = ProtocolConfig::paper_default(cfg.cycles);
        pc.variant = jd.variant;
        pc.learner = Learner::pegasos(e.lambda);
        pc.eval.n_peers = cfg.eval_peers;
        pc.seed = seed;
        pc.exec = cfg.exec;
        pc.path = cfg.path;
        if jd.failures {
            pc = pc.with_extreme_failures();
        }
        let res = run(pc, &e.ds);
        SweepCell {
            dataset: e.ds.name.clone(),
            variant: jd.variant,
            failures: jd.failures,
            replicate: jd.replicate,
            seed,
            curve: res.curve,
            stats: res.stats,
        }
    })
}

/// Write sweep results as CSV, one file per (dataset, failure scenario).
pub fn to_csv(cells: &[SweepCell], dir: &std::path::Path) -> std::io::Result<()> {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<(String, bool), Vec<Curve>> = BTreeMap::new();
    for c in cells {
        let mut curve = c.curve.clone();
        curve.label = format!("p2pegasos-{}-r{}", c.variant.name(), c.replicate);
        groups.entry((c.dataset.clone(), c.failures)).or_default().push(curve);
    }
    for ((dataset, failures), curves) in groups {
        let f = dir.join(format!(
            "sweep_{dataset}_{}.csv",
            if failures { "af" } else { "nofail" }
        ));
        crate::eval::csv::write_curves(&f, &curves)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_indexed_preserves_submission_order() {
        let out = run_indexed(64, 8, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn run_indexed_serial_fallback() {
        assert_eq!(run_indexed(3, 1, |i| i + 1), vec![1, 2, 3]);
        assert!(run_indexed(0, 4, |i| i).is_empty());
    }

    #[test]
    fn run_jobs_heterogeneous_closures() {
        let base = vec![10usize, 20, 30];
        let jobs: Vec<Box<dyn Fn() -> usize + Sync>> = base
            .iter()
            .map(|&v| Box::new(move || v + 1) as Box<dyn Fn() -> usize + Sync>)
            .collect();
        assert_eq!(run_jobs(jobs, 2), vec![11, 21, 31]);
    }

    #[test]
    fn thread_count_positive() {
        assert!(thread_count() >= 1);
    }

    #[test]
    fn grid_enumerates_all_cells_in_order() {
        let mut cfg = SweepConfig::paper_grid(0.01, 3, 7);
        cfg.variants = vec![Variant::Mu];
        cfg.failures = vec![false];
        cfg.replicates = 2;
        cfg.eval_peers = 5;
        cfg.threads = 2;
        let cells = run_grid(&cfg);
        assert_eq!(cells.len(), 3 * 2); // 3 datasets x 2 replicates
        assert_eq!(cells[0].dataset, "reuters");
        assert_eq!(cells[0].replicate, 0);
        assert_eq!(cells[1].replicate, 1);
        assert_eq!(cells[2].dataset, "spambase");
        for c in &cells {
            assert!(!c.curve.points.is_empty());
            assert_eq!(
                c.seed,
                cell_seed(7, &c.dataset, c.variant, c.failures, c.replicate)
            );
        }
        // replicates are genuinely independent runs
        assert_ne!(cells[0].seed, cells[1].seed);
    }
}
