//! Topology figure (beyond the paper; DESIGN.md §16): convergence of
//! P2PegasosMU when the gossip overlay is constrained to a sparse graph, one
//! panel per Table-I dataset.  Curve order fixes the topology axis —
//! complete graph (the paper's baseline), ring:2, 2D torus grid, 4-regular
//! random graph, Barabási–Albert (m = 3) — all with the same seed, so the
//! curves differ only in who may talk to whom.  Runs execute in parallel
//! through the [`sweep`] job pool.

use crate::api::{NullObserver, RunSpec};
use crate::config::ExperimentSpec;
use crate::eval::tracker::Curve;
use crate::experiments::common::ExpDataset;
use crate::experiments::sweep;
use crate::gossip::create_model::Variant;

/// The figure's topology axis: spec strings accepted by
/// [`crate::p2p::TopologySpec::parse`], sparsest last.
pub const TOPOLOGIES: [&str; 5] = ["complete", "ring:2", "grid", "kreg:4", "ba:3"];

pub struct TopoPanel {
    pub dataset: String,
    /// one curve per [`TOPOLOGIES`] entry, in order
    pub curves: Vec<Curve>,
}

type CurveJob<'a> = Box<dyn Fn() -> Curve + Sync + 'a>;

fn curve_jobs<'a>(e: &'a ExpDataset, cycles: u64, seed: u64) -> Vec<CurveJob<'a>> {
    TOPOLOGIES
        .iter()
        .map(|&topo| -> CurveJob<'a> {
            Box::new(move || {
                let spec = ExperimentSpec {
                    dataset: e.ds.name.clone(),
                    cycles,
                    variant: Variant::Mu,
                    lambda: e.lambda,
                    seed,
                    topology: crate::p2p::TopologySpec::parse(topo)
                        .expect("figure topology specs are valid"),
                    ..Default::default()
                };
                let outcome = RunSpec::from_spec(spec)
                    .build_with(&e.ds)
                    .expect("figure spec is valid")
                    .run(&mut NullObserver)
                    .expect("native event-driven run");
                let mut c = outcome.into_run().expect("sim outcome").curve;
                c.label = format!("p2pegasos-mu-{topo}");
                c
            })
        })
        .collect()
}

pub fn panel(e: &ExpDataset, cycles: u64, seed: u64) -> TopoPanel {
    let curves = sweep::run_jobs(curve_jobs(e, cycles, seed), sweep::thread_count());
    TopoPanel { dataset: e.ds.name.clone(), curves }
}

pub fn run_figure(sets: &[ExpDataset], cycles_override: Option<u64>, seed: u64) -> Vec<TopoPanel> {
    run_figure_threads(sets, cycles_override, seed, sweep::thread_count())
}

pub fn run_figure_threads(
    sets: &[ExpDataset],
    cycles_override: Option<u64>,
    seed: u64,
    threads: usize,
) -> Vec<TopoPanel> {
    let mut groups: Vec<(String, Vec<CurveJob>)> = Vec::new();
    for e in sets {
        let cycles = cycles_override.unwrap_or(e.cycles);
        groups.push((e.ds.name.clone(), curve_jobs(e, cycles, seed)));
    }
    sweep::run_grouped(groups, threads)
        .into_iter()
        .map(|(dataset, curves)| TopoPanel { dataset, curves })
        .collect()
}

pub fn to_csv(panels: &[TopoPanel], dir: &std::path::Path) -> std::io::Result<()> {
    for p in panels {
        let f = dir.join(format!("fig_topology_{}.csv", p.dataset));
        crate::eval::csv::write_curves(&f, &p.curves)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::datasets;

    #[test]
    fn panel_runs_every_topology_with_one_seed() {
        let sets = datasets(6, 0.02);
        let p = panel(&sets[2], 10, 4);
        assert_eq!(p.curves.len(), TOPOLOGIES.len());
        for (c, topo) in p.curves.iter().zip(TOPOLOGIES) {
            assert_eq!(c.label, format!("p2pegasos-mu-{topo}"));
            assert!(!c.points.is_empty());
            let last = c.points.last().unwrap();
            assert!(last.err_mean.is_finite() && last.err_mean <= 0.7);
        }
        // the complete-graph curve is bit-identical to an unconstrained run
        // with the same seed — `topology = complete` is the implicit default
        let spec = ExperimentSpec {
            dataset: sets[2].ds.name.clone(),
            cycles: 10,
            variant: Variant::Mu,
            lambda: sets[2].lambda,
            seed: 4,
            ..Default::default()
        };
        let base = RunSpec::from_spec(spec)
            .build_with(&sets[2].ds)
            .unwrap()
            .run(&mut NullObserver)
            .unwrap()
            .into_run()
            .unwrap();
        assert_eq!(p.curves[0].points.len(), base.curve.points.len());
        for (a, b) in p.curves[0].points.iter().zip(&base.curve.points) {
            assert_eq!(a.cycle, b.cycle);
            assert_eq!(a.err_mean.to_bits(), b.err_mean.to_bits());
            assert_eq!(a.messages_sent, b.messages_sent);
        }
    }
}
