//! Dense row-major f32 matrix — the in-memory model/feature container shared
//! by the native engine, the PJRT marshalling code, and the batched driver.

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "matrix shape mismatch");
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Disjoint mutable rows (for in-place pairwise ops).
    pub fn rows_mut2(&mut self, i: usize, j: usize) -> (&mut [f32], &mut [f32]) {
        assert!(i != j && i < self.rows && j < self.rows);
        let c = self.cols;
        if i < j {
            let (a, b) = self.data.split_at_mut(j * c);
            (&mut a[i * c..(i + 1) * c], &mut b[..c])
        } else {
            let (a, b) = self.data.split_at_mut(i * c);
            (&mut b[..c], &mut a[j * c..(j + 1) * c])
        }
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    pub fn copy_row_from(&mut self, i: usize, src: &[f32]) {
        self.row_mut(i).copy_from_slice(src);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_access() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.row(0), &[1., 2., 3.]);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn rows_mut2_disjoint_both_orders() {
        let mut m = Matrix::from_vec(3, 2, vec![0.; 6]);
        {
            let (a, b) = m.rows_mut2(0, 2);
            a[0] = 1.0;
            b[1] = 2.0;
        }
        assert_eq!(m.row(0), &[1., 0.]);
        assert_eq!(m.row(2), &[0., 2.]);
        {
            let (a, b) = m.rows_mut2(2, 0);
            a[0] = 9.0;
            b[0] = 8.0;
        }
        assert_eq!(m.row(2), &[9., 2.]);
        assert_eq!(m.row(0), &[8., 0.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Matrix::from_vec(2, 2, vec![0.; 3]);
    }
}
