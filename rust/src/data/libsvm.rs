//! libsvm/svmlight format parser, so the real UCI datasets (Reuters,
//! Spambase, Malicious URLs) can be dropped in place of the synthetic
//! generators when files are available (DESIGN.md §4).
//!
//! Format: one example per line, `label idx:value idx:value ...` with
//! 1-based feature indices.  Labels `0` and `-1` map to -1.

use crate::data::dataset::Examples;
use crate::data::sparse::Csr;
use std::io::BufRead;

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "libsvm parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a libsvm stream. `dims`: force a dimensionality (features beyond it
/// are rejected); `None` infers from the data.
pub fn parse<R: BufRead>(
    reader: R,
    dims: Option<usize>,
) -> Result<(Examples, Vec<f32>), ParseError> {
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut ys = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line.map_err(|e| ParseError { line: lineno + 1, msg: e.to_string() })?;
        let body = line.split('#').next().unwrap_or("").trim();
        if body.is_empty() {
            continue;
        }
        let mut parts = body.split_whitespace();
        let label_tok = parts.next().unwrap();
        let label: f32 = label_tok.parse().map_err(|_| ParseError {
            line: lineno + 1,
            msg: format!("bad label {label_tok:?}"),
        })?;
        let y = if label > 0.0 { 1.0 } else { -1.0 };

        let mut entries = Vec::new();
        for tok in parts {
            let (i_str, v_str) = tok.split_once(':').ok_or_else(|| ParseError {
                line: lineno + 1,
                msg: format!("bad feature token {tok:?}"),
            })?;
            let idx: usize = i_str.parse().map_err(|_| ParseError {
                line: lineno + 1,
                msg: format!("bad index {i_str:?}"),
            })?;
            let val: f32 = v_str.parse().map_err(|_| ParseError {
                line: lineno + 1,
                msg: format!("bad value {v_str:?}"),
            })?;
            if idx == 0 {
                return Err(ParseError {
                    line: lineno + 1,
                    msg: "libsvm indices are 1-based".into(),
                });
            }
            if let Some(d) = dims {
                if idx > d {
                    return Err(ParseError {
                        line: lineno + 1,
                        msg: format!("index {idx} exceeds dims {d}"),
                    });
                }
            }
            max_idx = max_idx.max(idx);
            entries.push(((idx - 1) as u32, val));
        }
        entries.sort_unstable_by_key(|e| e.0);
        rows.push(entries);
        ys.push(y);
    }

    let d = dims.unwrap_or(max_idx);
    let mut m = Csr::new(d.max(1));
    for r in &rows {
        m.push_row(r);
    }
    Ok((Examples::Sparse(m), ys))
}

/// Convenience: parse a file path.
pub fn load(path: &std::path::Path, dims: Option<usize>) -> anyhow::Result<(Examples, Vec<f32>)> {
    let f = std::fs::File::open(path)?;
    Ok(parse(std::io::BufReader::new(f), dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_lines() {
        let text = "+1 1:0.5 3:1.0\n-1 2:2.0\n0 1:1.0 # comment\n\n";
        let (x, y) = parse(text.as_bytes(), None).unwrap();
        assert_eq!(x.n(), 3);
        assert_eq!(x.d(), 3);
        assert_eq!(y, vec![1.0, -1.0, -1.0]);
        if let Examples::Sparse(m) = &x {
            assert_eq!(m.row(0), (&[0u32, 2][..], &[0.5f32, 1.0][..]));
        }
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse("+1 0:1.0".as_bytes(), None).is_err());
    }

    #[test]
    fn rejects_index_beyond_dims() {
        assert!(parse("+1 5:1.0".as_bytes(), Some(3)).is_err());
        assert!(parse("+1 3:1.0".as_bytes(), Some(3)).is_ok());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("abc 1:1.0".as_bytes(), None).is_err());
        assert!(parse("+1 1-1.0".as_bytes(), None).is_err());
        assert!(parse("+1 1:x".as_bytes(), None).is_err());
    }
}
