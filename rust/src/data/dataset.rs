//! Dataset container: fully-distributed training examples (one per node) plus
//! a held-out test set, with dense or sparse feature storage.

use crate::data::matrix::Matrix;
use crate::data::sparse::Csr;

/// A view of one example's feature vector.
#[derive(Clone, Copy, Debug)]
pub enum Row<'a> {
    Dense(&'a [f32]),
    Sparse(&'a [u32], &'a [f32]),
}

impl Row<'_> {
    /// <x, w> against a dense model.
    #[inline]
    pub fn dot(&self, w: &[f32]) -> f32 {
        match self {
            Row::Dense(x) => dense_dot(x, w),
            Row::Sparse(idx, val) => sparse_dot(idx, val, w),
        }
    }

    /// w += coef * x
    #[inline]
    pub fn add_scaled_into(&self, coef: f32, w: &mut [f32]) {
        match self {
            Row::Dense(x) => {
                for (wi, &xi) in w.iter_mut().zip(*x) {
                    *wi += coef * xi;
                }
            }
            Row::Sparse(idx, val) => {
                for (&j, &v) in idx.iter().zip(*val) {
                    w[j as usize] += coef * v;
                }
            }
        }
    }

    pub fn norm_sq(&self) -> f32 {
        match self {
            Row::Dense(x) => dense_dot(x, x),
            Row::Sparse(_, val) => val.iter().map(|v| v * v).sum(),
        }
    }

    pub fn to_dense(&self, d: usize) -> Vec<f32> {
        let mut out = vec![0.0; d];
        self.write_dense(&mut out);
        out
    }

    pub fn write_dense(&self, out: &mut [f32]) {
        match self {
            Row::Dense(x) => out[..x.len()].copy_from_slice(x),
            Row::Sparse(idx, val) => {
                out.fill(0.0);
                for (&j, &v) in idx.iter().zip(*val) {
                    out[j as usize] = v;
                }
            }
        }
    }
}

/// O(nnz) dot of a sparse row (indices, values) against a dense vector — the
/// single sparse-dot implementation shared by [`Row::dot`] (model margins,
/// `Predictor` voting), the engine's O(nnz) row kernels, and the batched
/// sparse evaluator.  Terms accumulate in index order, so every caller sees
/// the same float rounding.
#[inline]
pub fn sparse_dot(idx: &[u32], val: &[f32], w: &[f32]) -> f32 {
    let mut s = 0.0;
    for (&j, &v) in idx.iter().zip(val) {
        s += v * w[j as usize];
    }
    s
}

#[inline]
pub fn dense_dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane unrolled sum; autovectorizes well in release builds.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let k = i * 4;
        acc[0] += a[k] * b[k];
        acc[1] += a[k + 1] * b[k + 1];
        acc[2] += a[k + 2] * b[k + 2];
        acc[3] += a[k + 3] * b[k + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for k in chunks * 4..a.len() {
        s += a[k] * b[k];
    }
    s
}

/// Feature storage for a set of examples.
#[derive(Clone, Debug)]
pub enum Examples {
    Dense(Matrix),
    Sparse(Csr),
}

impl Examples {
    pub fn n(&self) -> usize {
        match self {
            Examples::Dense(m) => m.rows,
            Examples::Sparse(m) => m.rows,
        }
    }

    pub fn d(&self) -> usize {
        match self {
            Examples::Dense(m) => m.cols,
            Examples::Sparse(m) => m.cols,
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> Row<'_> {
        match self {
            Examples::Dense(m) => Row::Dense(m.row(i)),
            Examples::Sparse(m) => {
                let (idx, val) = m.row(i);
                Row::Sparse(idx, val)
            }
        }
    }

    /// Fraction of non-zero entries, nnz / (n · d) — the quantity the
    /// density-based sparse/dense execution dispatch thresholds on.
    pub fn density(&self) -> f64 {
        let cells = (self.n() * self.d()).max(1) as f64;
        match self {
            Examples::Dense(m) => {
                m.as_slice().iter().filter(|&&v| v != 0.0).count() as f64 / cells
            }
            Examples::Sparse(m) => m.nnz() as f64 / cells,
        }
    }

    /// Copy the examples into CSR form.  Used when the sparse execution path
    /// is forced (`--exec sparse`) on a densely stored dataset; sparse
    /// storage is cloned as-is.
    pub fn to_csr(&self) -> Csr {
        match self {
            Examples::Sparse(m) => m.clone(),
            Examples::Dense(m) => {
                let mut out = Csr::new(m.cols);
                let mut entries: Vec<(u32, f32)> = Vec::new();
                for i in 0..m.rows {
                    entries.clear();
                    for (j, &v) in m.row(i).iter().enumerate() {
                        if v != 0.0 {
                            entries.push((j as u32, v));
                        }
                    }
                    out.push_row(&entries);
                }
                out
            }
        }
    }
}

/// A binary-classification dataset in the fully-distributed model: `train`
/// has one row per network node; `test` is the held-out evaluation set.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub train: Examples,
    pub train_y: Vec<f32>,
    pub test: Examples,
    pub test_y: Vec<f32>,
}

impl Dataset {
    pub fn n_train(&self) -> usize {
        self.train.n()
    }

    pub fn n_test(&self) -> usize {
        self.test.n()
    }

    pub fn d(&self) -> usize {
        self.train.d()
    }

    /// (positives, negatives) in the training set.
    pub fn class_counts(&self) -> (usize, usize) {
        let pos = self.train_y.iter().filter(|&&y| y > 0.0).count();
        (pos, self.train_y.len() - pos)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.train.n() != self.train_y.len() {
            return Err("train size mismatch".into());
        }
        if self.test.n() != self.test_y.len() {
            return Err("test size mismatch".into());
        }
        if self.train.d() != self.test.d() {
            return Err("train/test dimension mismatch".into());
        }
        for &y in self.train_y.iter().chain(&self.test_y) {
            if y != 1.0 && y != -1.0 {
                return Err(format!("label {y} not in {{-1,+1}}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let train = Matrix::from_vec(2, 3, vec![1., 0., 0., 0., 1., 0.]);
        let test = Matrix::from_vec(1, 3, vec![0., 0., 1.]);
        Dataset {
            name: "tiny".into(),
            train: Examples::Dense(train),
            train_y: vec![1.0, -1.0],
            test: Examples::Dense(test),
            test_y: vec![1.0],
        }
    }

    #[test]
    fn dot_dense_sparse_agree() {
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let dense = [0.0, 1.5, 0.0, -2.0, 0.0];
        let mut csr = Csr::new(5);
        csr.push_row(&[(1, 1.5), (3, -2.0)]);
        let (idx, val) = csr.row(0);
        let a = Row::Dense(&dense).dot(&w);
        let b = Row::Sparse(idx, val).dot(&w);
        assert_eq!(a, b);
        assert_eq!(a, 1.5 * 2.0 - 2.0 * 4.0);
    }

    #[test]
    fn add_scaled_agree() {
        let dense = [0.0, 1.5, 0.0, -2.0, 0.0];
        let mut csr = Csr::new(5);
        csr.push_row(&[(1, 1.5), (3, -2.0)]);
        let mut w1 = vec![1.0; 5];
        let mut w2 = vec![1.0; 5];
        Row::Dense(&dense).add_scaled_into(2.0, &mut w1);
        let (idx, val) = csr.row(0);
        Row::Sparse(idx, val).add_scaled_into(2.0, &mut w2);
        assert_eq!(w1, w2);
        assert_eq!(w1[1], 4.0);
    }

    #[test]
    fn dense_dot_matches_naive() {
        let a: Vec<f32> = (0..23).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..23).map(|i| (23 - i) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dense_dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn validate_catches_bad_labels() {
        let mut ds = tiny();
        assert!(ds.validate().is_ok());
        ds.train_y[0] = 0.5;
        assert!(ds.validate().is_err());
    }

    #[test]
    fn class_counts() {
        assert_eq!(tiny().class_counts(), (1, 1));
    }

    #[test]
    fn density_counts_nonzeros_for_both_storages() {
        let ds = tiny(); // 2x3 train with 2 non-zeros
        assert!((ds.train.density() - 2.0 / 6.0).abs() < 1e-12);
        let mut csr = Csr::new(4);
        csr.push_row(&[(0, 1.0), (2, 2.0)]);
        csr.push_row(&[(3, -1.0)]);
        assert!((Examples::Sparse(csr).density() - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn to_csr_roundtrips_dense_rows() {
        let ds = tiny();
        let csr = ds.train.to_csr();
        assert_eq!(csr.rows, 2);
        assert_eq!(csr.cols, 3);
        let mut out = vec![0.0; 3];
        for i in 0..2 {
            csr.row_to_dense(i, &mut out);
            if let Examples::Dense(m) = &ds.train {
                assert_eq!(out, m.row(i));
            }
        }
        // sparse storage is cloned verbatim
        let mut sp = Csr::new(2);
        sp.push_row(&[(1, 4.0)]);
        let ex = Examples::Sparse(sp);
        let back = ex.to_csr();
        assert_eq!(back.row(0), (&[1u32][..], &[4.0f32][..]));
    }
}
