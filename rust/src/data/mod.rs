//! Data substrate: dense/sparse containers, the fully-distributed dataset
//! abstraction, synthetic Table-I generators, libsvm loading, feature
//! selection, and splitting.
pub mod dataset;
pub mod features;
pub mod libsvm;
pub mod matrix;
pub mod sparse;
pub mod split;
pub mod synthetic;

pub use dataset::{Dataset, Examples, Row};
pub use matrix::Matrix;
pub use sparse::Csr;
