//! Feature engineering used by the paper: correlation-coefficient feature
//! selection (Section VI-A(f), applied to the Malicious URLs set to reduce
//! ~3M features to 10) and projection onto the selected subspace.

use crate::data::dataset::{Examples, Row};
use crate::data::matrix::Matrix;

/// Pearson correlation of every feature with the label; returns the indices
/// of the `k` features with the largest |r|, in decreasing |r| order.
pub fn correlation_select(x: &Examples, y: &[f32], k: usize) -> Vec<usize> {
    let (n, d) = (x.n(), x.d());
    assert_eq!(n, y.len());
    let nf = n as f64;
    let sy: f64 = y.iter().map(|&v| v as f64).sum();
    let sy2: f64 = y.iter().map(|&v| (v as f64) * (v as f64)).sum();

    let mut sx = vec![0.0f64; d];
    let mut sx2 = vec![0.0f64; d];
    let mut sxy = vec![0.0f64; d];
    for i in 0..n {
        let yi = y[i] as f64;
        match x.row(i) {
            Row::Dense(r) => {
                for (j, &v) in r.iter().enumerate() {
                    let v = v as f64;
                    sx[j] += v;
                    sx2[j] += v * v;
                    sxy[j] += v * yi;
                }
            }
            Row::Sparse(idx, val) => {
                for (&j, &v) in idx.iter().zip(val) {
                    let v = v as f64;
                    sx[j as usize] += v;
                    sx2[j as usize] += v * v;
                    sxy[j as usize] += v * yi;
                }
            }
        }
    }

    let var_y = nf * sy2 - sy * sy;
    let mut scored: Vec<(usize, f64)> = (0..d)
        .map(|j| {
            let var_x = nf * sx2[j] - sx[j] * sx[j];
            let cov = nf * sxy[j] - sx[j] * sy;
            let denom = (var_x * var_y).sqrt();
            let r = if denom > 0.0 { cov / denom } else { 0.0 };
            (j, r.abs())
        })
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    scored.truncate(k);
    scored.into_iter().map(|(j, _)| j).collect()
}

/// Project examples onto the selected feature indices (dense output).
pub fn project(x: &Examples, keep: &[usize]) -> Matrix {
    let n = x.n();
    let mut out = Matrix::zeros(n, keep.len());
    // inverse map for sparse rows
    let mut inv = vec![usize::MAX; x.d()];
    for (new_j, &old_j) in keep.iter().enumerate() {
        inv[old_j] = new_j;
    }
    for i in 0..n {
        match x.row(i) {
            Row::Dense(r) => {
                let dst = out.row_mut(i);
                for (new_j, &old_j) in keep.iter().enumerate() {
                    dst[new_j] = r[old_j];
                }
            }
            Row::Sparse(idx, val) => {
                let dst = out.row_mut(i);
                for (&j, &v) in idx.iter().zip(val) {
                    let nj = inv[j as usize];
                    if nj != usize::MAX {
                        dst[nj] = v;
                    }
                }
            }
        }
    }
    out
}

/// Per-feature max-|v| scaling to [-1, 1] (utility for real libsvm data).
pub fn max_abs_scale(m: &mut Matrix) {
    let (rows, cols) = (m.rows, m.cols);
    let mut maxes = vec![0.0f32; cols];
    for i in 0..rows {
        for (j, &v) in m.row(i).iter().enumerate() {
            maxes[j] = maxes[j].max(v.abs());
        }
    }
    for i in 0..rows {
        let r = m.row_mut(i);
        for j in 0..cols {
            if maxes[j] > 0.0 {
                r[j] /= maxes[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Csr;
    use crate::util::rng::Rng;

    #[test]
    fn selects_informative_features() {
        // feature 2 == label, feature 0 anti-correlated, feature 1 noise
        let mut rng = Rng::new(4);
        let n = 400;
        let mut data = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let label = rng.sign();
            data.push(-label + 0.1 * rng.normal() as f32);
            data.push(rng.normal() as f32);
            data.push(label);
            y.push(label);
        }
        let x = Examples::Dense(Matrix::from_vec(n, 3, data));
        let keep = correlation_select(&x, &y, 2);
        assert_eq!(keep[0], 2);
        assert_eq!(keep[1], 0);
    }

    #[test]
    fn sparse_and_dense_selection_agree() {
        let mut rng = Rng::new(9);
        let (n, d) = (200, 12);
        let mut dense = Vec::new();
        let mut csr = Csr::new(d);
        let mut y = Vec::new();
        for _ in 0..n {
            let label = rng.sign();
            let mut entries = Vec::new();
            for j in 0..d {
                let v = if j < 3 && rng.chance(0.6) {
                    label * (1.0 + j as f32)
                } else if rng.chance(0.2) {
                    rng.normal() as f32
                } else {
                    0.0
                };
                dense.push(v);
                if v != 0.0 {
                    entries.push((j as u32, v));
                }
            }
            csr.push_row(&entries);
            y.push(label);
        }
        let a = correlation_select(&Examples::Dense(Matrix::from_vec(n, d, dense)), &y, 4);
        let b = correlation_select(&Examples::Sparse(csr), &y, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn project_maps_columns() {
        let m = Matrix::from_vec(2, 4, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let p = project(&Examples::Dense(m), &[3, 1]);
        assert_eq!(p.row(0), &[4., 2.]);
        assert_eq!(p.row(1), &[8., 6.]);
    }

    #[test]
    fn max_abs_scale_bounds() {
        let mut m = Matrix::from_vec(2, 2, vec![2.0, -8.0, -4.0, 0.0]);
        max_abs_scale(&mut m);
        assert_eq!(m.row(0), &[0.5, -1.0]);
        assert_eq!(m.row(1), &[-1.0, 0.0]);
    }
}
