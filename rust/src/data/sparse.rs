//! CSR sparse matrix for high-dimensional datasets (the Reuters-like set has
//! d = 9947 with ~60 non-zeros per row; the raw URLs-like set is sparse too).
//! Models stay dense; only example rows are sparse.

#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn new(cols: usize) -> Self {
        Csr { rows: 0, cols, indptr: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    /// Append a row given (sorted or unsorted) index/value pairs.
    ///
    /// The stored row is canonical CSR: strictly increasing column indices
    /// with duplicate entries summed and exact zeros dropped.  The
    /// merge-based sparse dots and the engine's O(nnz) row kernels rely on
    /// sorted rows, so canonicalization happens here, on insert.
    pub fn push_row(&mut self, entries: &[(u32, f32)]) {
        let sorted = entries.windows(2).all(|w| w[0].0 < w[1].0);
        if sorted {
            // common case (libsvm files and the synthetic generators emit
            // sorted rows): no allocation, no re-ordering
            for &(i, v) in entries {
                assert!((i as usize) < self.cols, "column index out of range");
                if v != 0.0 {
                    self.indices.push(i);
                    self.values.push(v);
                }
            }
        } else {
            let mut es = entries.to_vec();
            es.sort_by_key(|e| e.0);
            let mut k = 0;
            while k < es.len() {
                let (i, mut v) = es[k];
                assert!((i as usize) < self.cols, "column index out of range");
                k += 1;
                while k < es.len() && es[k].0 == i {
                    v += es[k].1;
                    k += 1;
                }
                if v != 0.0 {
                    self.indices.push(i);
                    self.values.push(v);
                }
            }
        }
        self.rows += 1;
        self.indptr.push(self.indices.len());
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn row_to_dense(&self, i: usize, out: &mut [f32]) {
        out.fill(0.0);
        let (idx, val) = self.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            out[j as usize] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read_rows() {
        let mut m = Csr::new(5);
        m.push_row(&[(0, 1.0), (3, 2.0)]);
        m.push_row(&[]);
        m.push_row(&[(4, -1.0)]);
        assert_eq!(m.rows, 3);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row(0), (&[0u32, 3][..], &[1.0f32, 2.0][..]));
        assert_eq!(m.row(1).0.len(), 0);
        let mut d = vec![0.0; 5];
        m.row_to_dense(2, &mut d);
        assert_eq!(d, vec![0.0, 0.0, 0.0, 0.0, -1.0]);
    }

    #[test]
    fn zero_values_skipped() {
        let mut m = Csr::new(3);
        m.push_row(&[(0, 0.0), (1, 2.0)]);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn unsorted_and_duplicate_entries_are_canonicalized() {
        let mut m = Csr::new(6);
        // unsorted, with a duplicated column (3) and a pair that cancels (5)
        m.push_row(&[(3, 1.0), (0, 2.0), (3, 0.5), (5, 1.0), (1, -1.0), (5, -1.0)]);
        assert_eq!(m.row(0), (&[0u32, 1, 3][..], &[2.0f32, -1.0, 1.5][..]));
        // sorted input is stored as-is
        m.push_row(&[(2, 4.0), (4, -3.0)]);
        assert_eq!(m.row(1), (&[2u32, 4][..], &[4.0f32, -3.0][..]));
        // every stored row ends up strictly increasing
        for i in 0..m.rows {
            let (idx, _) = m.row(i);
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "row {i} not sorted");
        }
    }

    #[test]
    #[should_panic]
    fn col_out_of_range() {
        let mut m = Csr::new(3);
        m.push_row(&[(3, 1.0)]);
    }
}
