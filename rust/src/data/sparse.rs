//! CSR sparse matrix for high-dimensional datasets (the Reuters-like set has
//! d = 9947 with ~60 non-zeros per row; the raw URLs-like set is sparse too).
//! Models stay dense; only example rows are sparse.

#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn new(cols: usize) -> Self {
        Csr { rows: 0, cols, indptr: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    /// Append a row given (sorted or unsorted) index/value pairs.
    pub fn push_row(&mut self, entries: &[(u32, f32)]) {
        for &(i, v) in entries {
            assert!((i as usize) < self.cols, "column index out of range");
            if v != 0.0 {
                self.indices.push(i);
                self.values.push(v);
            }
        }
        self.rows += 1;
        self.indptr.push(self.indices.len());
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn row_to_dense(&self, i: usize, out: &mut [f32]) {
        out.fill(0.0);
        let (idx, val) = self.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            out[j as usize] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read_rows() {
        let mut m = Csr::new(5);
        m.push_row(&[(0, 1.0), (3, 2.0)]);
        m.push_row(&[]);
        m.push_row(&[(4, -1.0)]);
        assert_eq!(m.rows, 3);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row(0), (&[0u32, 3][..], &[1.0f32, 2.0][..]));
        assert_eq!(m.row(1).0.len(), 0);
        let mut d = vec![0.0; 5];
        m.row_to_dense(2, &mut d);
        assert_eq!(d, vec![0.0, 0.0, 0.0, 0.0, -1.0]);
    }

    #[test]
    fn zero_values_skipped() {
        let mut m = Csr::new(3);
        m.push_row(&[(0, 0.0), (1, 2.0)]);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    #[should_panic]
    fn col_out_of_range() {
        let mut m = Csr::new(3);
        m.push_row(&[(3, 1.0)]);
    }
}
