//! Train/test splitting and row-subsetting utilities (used when loading real
//! libsvm data, and by the URLs-like 10k-sample training subset per
//! Section VI-A(h)).

use crate::data::dataset::Examples;
use crate::data::matrix::Matrix;
use crate::data::sparse::Csr;
use crate::util::rng::Rng;

/// Select a subset of rows (in the given order) into a new container.
pub fn select_rows(x: &Examples, idx: &[usize]) -> Examples {
    match x {
        Examples::Dense(m) => {
            let mut out = Matrix::zeros(idx.len(), m.cols);
            for (new_i, &old_i) in idx.iter().enumerate() {
                out.copy_row_from(new_i, m.row(old_i));
            }
            Examples::Dense(out)
        }
        Examples::Sparse(m) => {
            let mut out = Csr::new(m.cols);
            let mut buf = Vec::new();
            for &old_i in idx {
                let (ix, vals) = m.row(old_i);
                buf.clear();
                buf.extend(ix.iter().copied().zip(vals.iter().copied()));
                out.push_row(&buf);
            }
            Examples::Sparse(out)
        }
    }
}

pub fn select_labels(y: &[f32], idx: &[usize]) -> Vec<f32> {
    idx.iter().map(|&i| y[i]).collect()
}

/// Random split into (train, test) with `test_frac` of rows in the test set.
pub fn random_split(
    x: &Examples,
    y: &[f32],
    test_frac: f64,
    seed: u64,
) -> ((Examples, Vec<f32>), (Examples, Vec<f32>)) {
    let n = x.n();
    let mut rng = Rng::new(seed);
    let perm = rng.permutation(n);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let (test_idx, train_idx) = perm.split_at(n_test);
    (
        (select_rows(x, train_idx), select_labels(y, train_idx)),
        (select_rows(x, test_idx), select_labels(y, test_idx)),
    )
}

/// Uniform random subsample of k rows without replacement.
pub fn subsample(x: &Examples, y: &[f32], k: usize, seed: u64) -> (Examples, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let idx = rng.sample_indices(x.n(), k);
    (select_rows(x, &idx), select_labels(y, &idx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Row;

    fn dense4() -> (Examples, Vec<f32>) {
        let m = Matrix::from_vec(4, 2, vec![1., 1., 2., 2., 3., 3., 4., 4.]);
        (Examples::Dense(m), vec![1.0, -1.0, 1.0, -1.0])
    }

    #[test]
    fn select_rows_dense() {
        let (x, y) = dense4();
        let s = select_rows(&x, &[2, 0]);
        if let Examples::Dense(m) = s {
            assert_eq!(m.row(0), &[3., 3.]);
            assert_eq!(m.row(1), &[1., 1.]);
        }
        assert_eq!(select_labels(&y, &[2, 0]), vec![1.0, 1.0]);
    }

    #[test]
    fn select_rows_sparse() {
        let mut c = Csr::new(3);
        c.push_row(&[(0, 1.0)]);
        c.push_row(&[(2, 5.0)]);
        let s = select_rows(&Examples::Sparse(c), &[1, 1]);
        match s.row(0) {
            Row::Sparse(i, v) => {
                assert_eq!(i, &[2]);
                assert_eq!(v, &[5.0]);
            }
            _ => panic!(),
        }
        assert_eq!(s.n(), 2);
    }

    #[test]
    fn random_split_partitions() {
        let (x, y) = dense4();
        let ((xtr, ytr), (xte, yte)) = random_split(&x, &y, 0.25, 1);
        assert_eq!(xtr.n(), 3);
        assert_eq!(xte.n(), 1);
        assert_eq!(ytr.len(), 3);
        assert_eq!(yte.len(), 1);
    }

    #[test]
    fn subsample_size_and_determinism() {
        let (x, y) = dense4();
        let (a, ya) = subsample(&x, &y, 2, 9);
        let (b, yb) = subsample(&x, &y, 2, 9);
        assert_eq!(a.n(), 2);
        assert_eq!(ya, yb);
        if let (Examples::Dense(ma), Examples::Dense(mb)) = (&a, &b) {
            assert_eq!(ma.as_slice(), mb.as_slice());
        }
    }
}
