//! Synthetic stand-ins for the paper's three UCI datasets (Table I).
//!
//! The build environment has no network access, so the real Reuters /
//! Spambase / Malicious-URLs files cannot be fetched.  Each generator below
//! matches the corresponding dataset's *shape statistics* from Table I —
//! train/test size, dimensionality, class ratio, sparsity pattern — and its
//! noise level is tuned so the sequential Pegasos baseline lands near the
//! paper's reported 0-1 error (0.025 / 0.111 / 0.080).  All gossip-learning
//! claims are about convergence dynamics *relative to baselines on the same
//! data*, which this substitution preserves: every algorithm consumes
//! identical samples.  Real UCI files in libsvm format can be dropped in via
//! `data::libsvm` instead (DESIGN.md §4).

use crate::data::dataset::{Dataset, Examples};
use crate::data::matrix::Matrix;
use crate::data::sparse::Csr;
use crate::util::rng::Rng;

/// Size-reduction knob for tests/examples: scales the number of rows while
/// keeping dimensionality and class ratios intact.
#[derive(Clone, Copy, Debug)]
pub struct Scale(pub f64);

impl Scale {
    pub const FULL: Scale = Scale(1.0);

    fn apply(&self, n: usize) -> usize {
        ((n as f64 * self.0).round() as usize).max(8)
    }
}

/// Spambase-like: d=57 dense, 4140 train / 461 test, 1813:2788 class ratio,
/// Pegasos-20k target error ≈ 0.111.
pub fn spambase_like(seed: u64, scale: Scale) -> Dataset {
    let (n_train, n_test) = (scale.apply(4140), scale.apply(461));
    let d = 57;
    let pos_frac = 1813.0 / 4601.0;
    let noise_flip = 0.095;
    let mut rng = Rng::new(seed ^ 0x5BA5);

    // Fixed per-dataset anisotropic feature scales (spambase features have
    // wildly different ranges: word freqs vs capital-run lengths).  Feature 0
    // is a constant indicator column (akin to spambase's near-constant
    // frequency features); it lets the through-origin Pegasos model — the
    // paper's Algorithm 3 carries no bias term — represent the class-ratio
    // threshold exactly.
    let scales: Vec<f32> =
        (0..d).map(|_| rng.lognormal(0.0, 0.4) as f32).collect();
    let w_star: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();

    #[allow(unused_mut)]
    let gen = |rng: &mut Rng, n: usize| {
        let mut xs = Vec::with_capacity(n * d);
        let mut zs = Vec::with_capacity(n);
        for _ in 0..n {
            let mut z = 0.0f32;
            for j in 0..d {
                let x = if j == 0 {
                    1.0
                } else if rng.chance(0.35) {
                    (rng.normal() as f32).abs() * scales[j]
                } else {
                    0.0
                };
                xs.push(x);
                if j > 0 {
                    z += x * w_star[j];
                }
            }
            zs.push(z);
        }
        (Matrix::from_vec(n, d, xs), zs)
    };

    let (train, ztr) = gen(&mut rng, n_train);
    let (test, zte) = gen(&mut rng, n_test);
    // threshold at the empirical quantile so the class ratio matches Table I;
    // representable through the origin via the constant feature 0.
    let theta = quantile(&ztr, 1.0 - pos_frac);
    let label = |rng: &mut Rng, z: f32| {
        let y = if z > theta { 1.0 } else { -1.0 };
        if rng.chance(noise_flip) {
            -y
        } else {
            y
        }
    };
    let train_y: Vec<f32> = ztr.iter().map(|&z| label(&mut rng, z)).collect();
    let test_y: Vec<f32> = zte.iter().map(|&z| label(&mut rng, z)).collect();

    Dataset {
        name: "spambase".into(),
        train: Examples::Dense(train),
        train_y,
        test: Examples::Dense(test),
        test_y,
    }
}

/// Reuters-like: d=9947 sparse binary bag-of-words, 2000 train / 600 test,
/// balanced classes, near-separable; Pegasos-20k target error ≈ 0.025.
pub fn reuters_like(seed: u64, scale: Scale) -> Dataset {
    let (n_train, n_test) = (scale.apply(2000), scale.apply(600));
    let d = 9947;
    let class_block = 900; // features [0,900) favor +1, [900,1800) favor -1
    let shared_lo = 1800;
    let words_per_doc = 60;
    let noise_flip = 0.022;
    let mut rng = Rng::new(seed ^ 0x2E07E);

    let gen = |rng: &mut Rng, n: usize| -> (Csr, Vec<f32>) {
        let mut m = Csr::new(d);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let y: f32 = if i % 2 == 0 { 1.0 } else { -1.0 };
            let mut entries = Vec::with_capacity(words_per_doc);
            let mut seen = std::collections::HashSet::new();
            while entries.len() < words_per_doc {
                let j = if rng.chance(0.25) {
                    // class-indicative word
                    let block = if y > 0.0 { 0 } else { class_block };
                    block + rng.below_usize(class_block)
                } else {
                    shared_lo + rng.below_usize(d - shared_lo)
                };
                if seen.insert(j) {
                    entries.push((j as u32, 1.0f32));
                }
            }
            entries.sort_unstable_by_key(|e| e.0);
            m.push_row(&entries);
            let y = if rng.chance(noise_flip) { -y } else { y };
            ys.push(y);
        }
        (m, ys)
    };

    let (train, train_y) = gen(&mut rng, n_train);
    let (test, test_y) = gen(&mut rng, n_test);
    Dataset {
        name: "reuters".into(),
        train: Examples::Sparse(train),
        train_y,
        test: Examples::Sparse(test),
        test_y,
    }
}

/// Malicious-URLs-like: the paper reduces ~3M features to the 10 with the
/// highest |correlation| with the label, then trains on a 10,000-example
/// random subsample and evaluates on the 240,508-example test set.
/// We generate a raw d=200 sparse set (20 informative features + 180 noise),
/// apply the same correlation-coefficient selection (data::features), and
/// return the dense d=10 dataset.  Class ratio 792145:1603985 ≈ 33% positive;
/// Pegasos-20k target error ≈ 0.080.
pub fn urls_like(seed: u64, scale: Scale) -> Dataset {
    let (n_train, n_test) = (scale.apply(10_000), scale.apply(240_508));
    let d_raw = 200;
    let n_informative = 20;
    let pos_frac = 792_145.0 / 2_396_130.0;
    let noise_flip = 0.065;
    let mut rng = Rng::new(seed ^ 0x0261);

    // informative feature j fires with rate r+ for class +1 and r- for -1
    let mut rates_pos = vec![0.05f64; d_raw];
    let mut rates_neg = vec![0.05f64; d_raw];
    for j in 0..n_informative {
        let strength = 0.25 + 0.5 * rng.next_f64();
        if j % 2 == 0 {
            rates_pos[j] = strength;
            rates_neg[j] = 0.05;
        } else {
            rates_pos[j] = 0.05;
            rates_neg[j] = strength;
        }
    }

    let gen = |rng: &mut Rng, n: usize| -> (Csr, Vec<f32>) {
        let mut m = Csr::new(d_raw);
        let mut ys = Vec::with_capacity(n);
        let mut entries = Vec::new();
        for _ in 0..n {
            let mut y: f32 = if rng.chance(pos_frac) { 1.0 } else { -1.0 };
            let rates = if y > 0.0 { &rates_pos } else { &rates_neg };
            entries.clear();
            for j in 0..d_raw {
                if rng.chance(rates[j]) {
                    entries.push((j as u32, 1.0f32));
                }
            }
            m.push_row(&entries);
            if rng.chance(noise_flip) {
                y = -y;
            }
            ys.push(y);
        }
        (m, ys)
    };

    let (train_raw, train_y) = gen(&mut rng, n_train);
    let (test_raw, test_y) = gen(&mut rng, n_test);

    // The paper's offline feature-reduction step (Section VI-A(f)).
    let train_ex = Examples::Sparse(train_raw);
    let keep = crate::data::features::correlation_select(&train_ex, &train_y, 10);
    let train = crate::data::features::project(&train_ex, &keep);
    let test = crate::data::features::project(&Examples::Sparse(test_raw), &keep);

    Dataset {
        name: "urls".into(),
        train: Examples::Dense(train),
        train_y,
        test: Examples::Dense(test),
        test_y,
    }
}

/// All three Table-I datasets at the given scale.
pub fn all(seed: u64, scale: Scale) -> Vec<Dataset> {
    vec![
        reuters_like(seed, scale),
        spambase_like(seed, scale),
        urls_like(seed, scale),
    ]
}

fn quantile(xs: &[f32], q: f64) -> f32 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() as f64 - 1.0) * q).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spambase_shape_and_ratio() {
        let ds = spambase_like(1, Scale::FULL);
        assert_eq!(ds.n_train(), 4140);
        assert_eq!(ds.n_test(), 461);
        assert_eq!(ds.d(), 57);
        ds.validate().unwrap();
        let (pos, neg) = ds.class_counts();
        let frac = pos as f64 / (pos + neg) as f64;
        assert!((frac - 0.394).abs() < 0.04, "pos frac {frac}");
    }

    #[test]
    fn reuters_shape_sparse() {
        let ds = reuters_like(1, Scale(0.1));
        assert_eq!(ds.d(), 9947);
        ds.validate().unwrap();
        if let Examples::Sparse(m) = &ds.train {
            let nnz_per_row = m.nnz() as f64 / m.rows as f64;
            assert!((nnz_per_row - 60.0).abs() < 2.0);
        } else {
            panic!("expected sparse");
        }
    }

    #[test]
    fn urls_reduced_to_ten_dense_features() {
        let ds = urls_like(1, Scale(0.01));
        assert_eq!(ds.d(), 10);
        ds.validate().unwrap();
        assert!(matches!(ds.train, Examples::Dense(_)));
        let (pos, neg) = ds.class_counts();
        let frac = pos as f64 / (pos + neg) as f64;
        assert!((frac - 0.33).abs() < 0.08, "pos frac {frac}");
    }

    #[test]
    fn generators_deterministic() {
        let a = spambase_like(7, Scale(0.05));
        let b = spambase_like(7, Scale(0.05));
        assert_eq!(a.train_y, b.train_y);
        if let (Examples::Dense(x), Examples::Dense(y)) = (&a.train, &b.train) {
            assert_eq!(x.as_slice(), y.as_slice());
        }
    }

    #[test]
    fn scale_reduces_rows() {
        let ds = spambase_like(1, Scale(0.1));
        assert_eq!(ds.n_train(), 414);
    }
}
