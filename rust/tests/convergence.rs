//! End-to-end convergence: the gossip learner must reach dataset-appropriate
//! error levels on all three Table-I workloads, and the paper's qualitative
//! orderings must hold (WB1 ≼ WB2 ≼ MU ≼ RW ≼ sequential in convergence
//! speed; merging beats no merging).


#![allow(deprecated)] // this suite pins the legacy shims (run/run_batched/run_deployment) bit-for-bit
use golf::baselines::sequential;
use golf::baselines::weighted_bagging::{curve as wb_curve, Bagging};
use golf::data::synthetic::{reuters_like, spambase_like, urls_like, Scale};
use golf::eval::tracker::Curve;
use golf::gossip::create_model::Variant;
use golf::gossip::protocol::{run, ProtocolConfig};
use golf::learning::Learner;

fn cfg(cycles: u64, variant: Variant, seed: u64) -> ProtocolConfig {
    let mut c = ProtocolConfig::paper_default(cycles);
    c.variant = variant;
    c.eval.n_peers = 30;
    c.seed = seed;
    c
}

fn auc(c: &Curve) -> f64 {
    c.points.iter().map(|p| p.err_mean).sum::<f64>() / c.points.len() as f64
}

#[test]
fn urls_reaches_low_error() {
    let ds = urls_like(31, Scale(0.05)); // 500 nodes
    let res = run(cfg(100, Variant::Mu, 1), &ds);
    assert!(
        res.curve.final_error() < 0.14,
        "final error {}",
        res.curve.final_error()
    );
}

#[test]
fn reuters_reaches_low_error() {
    let ds = reuters_like(32, Scale(0.1)); // 200 nodes, d=9947
    let res = run(cfg(120, Variant::Mu, 2), &ds);
    assert!(
        res.curve.final_error() < 0.15,
        "final error {}",
        res.curve.final_error()
    );
}

#[test]
fn spambase_reaches_moderate_error() {
    let ds = spambase_like(33, Scale(0.25)); // 1035 nodes
    let res = run(cfg(150, Variant::Mu, 3), &ds);
    assert!(
        res.curve.final_error() < 0.30,
        "final error {}",
        res.curve.final_error()
    );
}

#[test]
fn merging_speeds_up_convergence() {
    // the paper's central claim: MU ≺ RW in convergence speed
    let ds = urls_like(34, Scale(0.04));
    let mu = run(cfg(60, Variant::Mu, 4), &ds);
    let rw = run(cfg(60, Variant::Rw, 4), &ds);
    assert!(
        auc(&mu.curve) < auc(&rw.curve) + 1e-9,
        "mu {} vs rw {}",
        auc(&mu.curve),
        auc(&rw.curve)
    );
}

#[test]
fn wb1_dominates_gossip_dominates_sequential() {
    let ds = urls_like(35, Scale(0.04));
    let learner = Learner::pegasos(1e-2);
    let wb1 = wb_curve(&ds, &learner, Bagging::Wb1, 60, 5);
    let mu = run(cfg(60, Variant::Mu, 5), &ds);
    let seq = sequential::curve(&ds, &learner, 60, 5);
    let (a, b, c) = (auc(&wb1), auc(&mu.curve), auc(&seq));
    assert!(a <= b + 0.03, "wb1 {a} vs mu {b}");
    assert!(b <= c + 0.03, "mu {b} vs sequential {c}");
}

#[test]
fn adaline_gossip_converges_too() {
    let ds = urls_like(36, Scale(0.03));
    let mut c = cfg(60, Variant::Mu, 6);
    c.learner = Learner::adaline(0.05);
    let res = run(c, &ds);
    let first = res.curve.points.first().unwrap().err_mean;
    assert!(
        res.curve.final_error() < first,
        "{} -> {}",
        first,
        res.curve.final_error()
    );
}
