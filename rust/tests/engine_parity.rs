//! Engine parity: the native Rust backend and the PJRT backend (running the
//! AOT-compiled Pallas kernels) must produce the same trajectories on the
//! same batched schedule.  Combined with python/tests (kernels == ref.py)
//! this closes the chain: rust native == XLA == Pallas == paper math.
//!
//! Tests are skipped when `artifacts/manifest.tsv` is missing (run
//! `make artifacts` first).


#![allow(deprecated)] // this suite pins the legacy shims (run/run_batched/run_deployment) bit-for-bit
use golf::config::ExperimentSpec;
use golf::data::synthetic::{reuters_like, spambase_like, urls_like, Scale};
use golf::engine::batched::run_batched;
use golf::engine::native::NativeBackend;
use golf::engine::pjrt::PjrtBackend;
use golf::engine::{Backend, LearnerKind, StepBatch, StepOp};
use golf::experiments::sweep;
use golf::gossip::create_model::Variant;
use golf::gossip::protocol::{run, ExecMode, ExecPath, ProtocolConfig, RunResult};
use golf::learning::{Learner, MergeMode};
use golf::util::rng::Rng;

fn pjrt() -> Option<PjrtBackend> {
    let dir = PjrtBackend::default_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipping: no artifacts at {dir:?}");
        return None;
    }
    Some(PjrtBackend::new(&dir).expect("loading PJRT backend"))
}

fn random_batch(rng: &mut Rng, b: usize, d: usize) -> StepBatch {
    let mut sb = StepBatch::default();
    sb.resize(b, d);
    for v in sb.w1.iter_mut().chain(&mut sb.w2).chain(&mut sb.x) {
        *v = rng.normal() as f32;
    }
    for i in 0..b {
        sb.y[i] = rng.sign();
        sb.t1[i] = rng.below(100) as f32;
        sb.t2[i] = rng.below(100) as f32;
    }
    sb
}

#[test]
fn step_ops_match_native_all_variants() {
    let Some(mut pj) = pjrt() else { return };
    let mut nat = NativeBackend::new();
    let mut rng = Rng::new(11);
    for learner in [LearnerKind::Pegasos, LearnerKind::Adaline, LearnerKind::LogReg] {
        for variant in [Variant::Rw, Variant::Mu, Variant::Um] {
            let op = StepOp { learner, variant, hp: 0.01, merge: MergeMode::Average };
            let mut a = random_batch(&mut rng, 37, 13); // forces padding
            let mut b = a.clone();
            nat.step(&op, &mut a).unwrap();
            pj.step(&op, &mut b).unwrap();
            for (i, (x, y)) in a.out_w.iter().zip(&b.out_w).enumerate() {
                assert!(
                    (x - y).abs() < 1e-4 + 1e-4 * x.abs().max(y.abs()),
                    "{learner:?}/{variant:?} out_w[{i}]: native {x} vs pjrt {y}"
                );
            }
            assert_eq!(a.out_t, b.out_t, "{learner:?}/{variant:?} out_t");
        }
    }
}

#[test]
fn error_counts_match_native() {
    let Some(mut pj) = pjrt() else { return };
    let mut nat = NativeBackend::new();
    let mut rng = Rng::new(12);
    let (n, d, m) = (300, 10, 7);
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let w: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
    let mut y: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
    y[n - 1] = 0.0; // padding row
    let a = nat.error_counts(&x, &y, n, d, &w, m).unwrap();
    let b = pj.error_counts(&x, &y, n, d, &w, m).unwrap();
    assert_eq!(a, b);
}

#[test]
fn full_run_parity_urls() {
    let Some(mut pj) = pjrt() else { return };
    let ds = urls_like(21, Scale(0.01));
    let mut cfg = ProtocolConfig::paper_default(12);
    cfg.eval.n_peers = 10;
    let mut nat = NativeBackend::new();
    let a = run_batched(cfg.clone(), &ds, &mut nat).unwrap();
    let b = run_batched(cfg, &ds, &mut pj).unwrap();
    assert_eq!(a.curve.points.len(), b.curve.points.len());
    for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
        // native and XLA contract dots in different orders; test rows whose
        // margin sits at the f32 noise floor can flip — allow a few of the
        // ~5k test rows to differ
        assert!(
            (pa.err_mean - pb.err_mean).abs() < 2e-3,
            "cycle {}: native {} vs pjrt {}",
            pa.cycle,
            pa.err_mean,
            pb.err_mean
        );
    }
}

#[test]
fn full_run_parity_spambase_um() {
    let Some(mut pj) = pjrt() else { return };
    let ds = spambase_like(22, Scale(0.02));
    let mut cfg = ProtocolConfig::paper_default(8);
    cfg.variant = Variant::Um;
    cfg.eval.n_peers = 8;
    let mut nat = NativeBackend::new();
    let a = run_batched(cfg.clone(), &ds, &mut nat).unwrap();
    let b = run_batched(cfg, &ds, &mut pj).unwrap();
    for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
        // UM chains two updates per receive; allow f32 slack
        assert!(
            (pa.err_mean - pb.err_mean).abs() < 5e-3,
            "cycle {}: native {} vs pjrt {}",
            pa.cycle,
            pa.err_mean,
            pb.err_mean
        );
    }
}

fn assert_runs_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.curve.points.len(), b.curve.points.len(), "{what}: point counts");
    for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
        assert_eq!(pa.cycle, pb.cycle, "{what}");
        assert_eq!(pa.err_mean, pb.err_mean, "{what} @ cycle {}", pa.cycle);
        assert_eq!(pa.err_std, pb.err_std, "{what} @ cycle {}", pa.cycle);
        assert_eq!(pa.err_vote, pb.err_vote, "{what} @ cycle {}", pa.cycle);
        assert_eq!(pa.similarity, pb.similarity, "{what} @ cycle {}", pa.cycle);
        assert_eq!(pa.auc, pb.auc, "{what} @ cycle {}", pa.cycle);
        assert_eq!(pa.messages_sent, pb.messages_sent, "{what} @ cycle {}", pa.cycle);
    }
    assert_eq!(a.stats.messages_sent, b.stats.messages_sent, "{what}");
    assert_eq!(a.stats.messages_dropped, b.stats.messages_dropped, "{what}");
    assert_eq!(a.stats.messages_blocked, b.stats.messages_blocked, "{what}");
    assert_eq!(a.stats.messages_lost_offline, b.stats.messages_lost_offline, "{what}");
    assert_eq!(a.stats.messages_delivered, b.stats.messages_delivered, "{what}");
    assert_eq!(a.stats.updates_applied, b.stats.updates_applied, "{what}");
}

/// The event-driven micro-batched path must be bit-for-bit identical to the
/// scalar event-driven path on the same seed: micro-batching is a pure
/// reorganization of independent rows, with per-node chaining wired through
/// message weights.
#[test]
fn event_microbatch_bitwise_equals_scalar() {
    for (seed, failures) in [(61u64, false), (62, true)] {
        let ds = urls_like(seed, Scale(0.02));
        let mut cfg = ProtocolConfig::paper_default(30);
        cfg.eval.n_peers = 15;
        cfg.eval.voting = true;
        cfg.eval.similarity = true;
        cfg.seed = seed;
        if failures {
            cfg = cfg.with_extreme_failures();
        }
        let mut scalar_cfg = cfg.clone();
        scalar_cfg.exec = ExecMode::Scalar;
        let mut micro_cfg = cfg;
        micro_cfg.exec = ExecMode::MicroBatch { coalesce: 0 };
        let a = run(scalar_cfg, &ds);
        let b = run(micro_cfg, &ds);
        assert_runs_identical(&a, &b, &format!("scalar vs microbatch (failures={failures})"));
        assert!(
            b.stats.engine_calls <= a.stats.engine_calls,
            "micro-batching must not increase engine calls"
        );
    }
}

/// Same check across all three Table-I datasets and all learner variants at
/// small scale — the UM variant exercises the two-update row path.
#[test]
fn event_microbatch_bitwise_equals_scalar_all_datasets() {
    let sets = golf::experiments::datasets(63, 0.01);
    for e in &sets {
        for variant in [Variant::Rw, Variant::Mu, Variant::Um] {
            let mut cfg = ProtocolConfig::paper_default(8).with_extreme_failures();
            cfg.variant = variant;
            cfg.eval.n_peers = 8;
            cfg.seed = 63;
            let mut scalar_cfg = cfg.clone();
            scalar_cfg.exec = ExecMode::Scalar;
            let mut micro_cfg = cfg;
            micro_cfg.exec = ExecMode::MicroBatch { coalesce: 0 };
            let a = run(scalar_cfg, &e.ds);
            let b = run(micro_cfg, &e.ds);
            assert_runs_identical(&a, &b, &format!("{} {:?}", e.ds.name, variant));
        }
    }
}

/// Scenario timelines (DESIGN.md §11) mutate network/liveness/labels at
/// tick boundaries with pending micro-batches flushed first, so a scripted
/// drift + partition + leave run must stay bit-for-bit identical between
/// scalar and micro-batched stepping — for every CREATEMODEL variant.
#[test]
fn scenario_timeline_scalar_equals_microbatch_all_variants() {
    use golf::scenario::{
        DelaySpec, PartitionSpec, Phase, PointAction, PointEvent, Scenario,
    };
    let ds = urls_like(65, Scale(0.02));
    let mut scn = Scenario::empty("parity-timeline");
    scn.drop = Some(0.2);
    scn.phases.push(Phase {
        name: "split".into(),
        from: 5,
        to: 14,
        drop: None,
        delay: Some(DelaySpec::Uniform(0.5, 3.0)),
        partition: Some(PartitionSpec::Halves),
        leave: Some(0.2),
    });
    scn.events.push(PointEvent {
        name: "invert".into(),
        at: 18,
        action: PointAction::Drift,
    });
    scn.validate(ds.n_train(), 30).unwrap();
    for variant in [Variant::Rw, Variant::Mu, Variant::Um] {
        let mut cfg = ProtocolConfig::paper_default(30);
        cfg.variant = variant;
        cfg.eval.n_peers = 12;
        cfg.seed = 65;
        cfg.scenario = Some(scn.clone());
        let mut scalar_cfg = cfg.clone();
        scalar_cfg.exec = ExecMode::Scalar;
        let mut micro_cfg = cfg;
        micro_cfg.exec = ExecMode::MicroBatch { coalesce: 0 };
        let a = run(scalar_cfg, &ds);
        let b = run(micro_cfg, &ds);
        assert!(a.stats.messages_blocked > 0, "{variant:?}: partition must engage");
        assert_runs_identical(&a, &b, &format!("scenario scalar vs microbatch {variant:?}"));
    }
}

/// Window coalescing quantizes delivery times (a bounded, documented timing
/// approximation) — convergence must stay in the same regime as window 0.
#[test]
fn event_coalescing_window_stays_close() {
    let ds = urls_like(64, Scale(0.02));
    let mut cfg = ProtocolConfig::paper_default(40);
    cfg.eval.n_peers = 15;
    cfg.seed = 64;
    let exact = run(cfg.clone(), &ds);
    cfg.exec = ExecMode::MicroBatch { coalesce: cfg.delta / 4 };
    let coalesced = run(cfg, &ds);
    let (a, b) = (exact.curve.final_error(), coalesced.curve.final_error());
    assert!((a - b).abs() < 0.05, "window-0 {a} vs coalesced {b}");
    assert!(
        coalesced.stats.engine_calls < coalesced.stats.updates_applied,
        "coalescing should batch multiple deliveries per engine call"
    );
}

/// Acceptance: a parallel sweep of the three Table-I datasets with the
/// all-failures scenario produces curves identical to serial execution for
/// the same seeds.
#[test]
fn sweep_parallel_bitwise_equals_serial() {
    let mk = |threads: usize| {
        let mut cfg = sweep::SweepConfig::paper_grid(0.01, 10, 99);
        cfg.variants = vec![Variant::Mu];
        cfg.failures = vec![true];
        cfg.replicates = 2;
        cfg.eval_peers = 10;
        cfg.threads = threads;
        sweep::run_grid(&cfg).unwrap()
    };
    let serial = mk(1);
    let parallel = mk(4);
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(serial.len(), 3 * 2); // three datasets, all-failures, 2 reps
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.dataset, b.dataset);
        assert_eq!(a.variant, b.variant);
        assert_eq!(a.failures, b.failures);
        assert!(a.failures, "grid restricted to the all-failures scenario");
        assert_eq!(a.seed, b.seed, "derived seeds must not depend on threads");
        assert_eq!(a.curve.points.len(), b.curve.points.len());
        for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
            assert_eq!(pa.cycle, pb.cycle);
            assert_eq!(pa.err_mean, pb.err_mean, "{} parallel != serial", a.dataset);
        }
        assert_eq!(a.stats.messages_sent, b.stats.messages_sent);
    }
}

// ---------------------------------------------------------------------------
// Sparse-vs-dense execution path parity (DESIGN.md §7): the O(nnz)
// lazy-scale kernels against the dense `[b, d]` kernels, for every learner
// and CREATEMODEL variant.

/// Build a dense-layout batch plus its CSR-staged twin over the same rows.
fn dense_and_sparse_twin(
    rng: &mut Rng,
    b: usize,
    d: usize,
    nnz: usize,
) -> (StepBatch, StepBatch) {
    let mut dense = StepBatch::default();
    dense.resize(b, d);
    for v in dense.w1.iter_mut().chain(&mut dense.w2) {
        *v = rng.normal() as f32;
    }
    let mut idxs: Vec<Vec<u32>> = Vec::with_capacity(b);
    let mut vals: Vec<Vec<f32>> = Vec::with_capacity(b);
    for i in 0..b {
        dense.y[i] = rng.sign();
        dense.t1[i] = rng.below(50) as f32;
        dense.t2[i] = rng.below(50) as f32;
        let mut idx: Vec<u32> = (0..nnz).map(|_| rng.below(d as u64) as u32).collect();
        idx.sort_unstable();
        idx.dedup();
        let val: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
        for (&j, &v) in idx.iter().zip(&val) {
            dense.x[i * d + j as usize] = v;
        }
        idxs.push(idx);
        vals.push(val);
    }
    let mut sparse = dense.clone();
    sparse.resize_for(b, d, true);
    for i in 0..b {
        sparse.push_sparse_x_row(&idxs[i], &vals[i]);
    }
    (dense, sparse)
}

/// Per-coordinate agreement of the sparse and dense kernels on one step, for
/// all three learners × RW/MU/UM.  Lazy scaling legitimately reorders float
/// ops (scale product vs. per-coordinate decay, sparse vs. 4-lane dense
/// dots), so agreement is within a small tolerance rather than exact.
#[test]
fn sparse_kernels_match_dense_per_coordinate_all_learners_and_variants() {
    let mut nat = NativeBackend::new();
    let mut rng = Rng::new(71);
    let (b, d, nnz) = (16, 37, 6);
    for learner in [LearnerKind::Pegasos, LearnerKind::Adaline, LearnerKind::LogReg] {
        for variant in [Variant::Rw, Variant::Mu, Variant::Um] {
            let op = StepOp { learner, variant, hp: 0.05, merge: MergeMode::Average };
            let (mut dense, mut sparse) = dense_and_sparse_twin(&mut rng, b, d, nnz);
            nat.step(&op, &mut dense).unwrap();
            nat.step(&op, &mut sparse).unwrap();
            for i in 0..b {
                let s = sparse.out_s[i];
                for j in 0..d {
                    let a = dense.out_w[i * d + j];
                    let e = sparse.w1[i * d + j] * s;
                    assert!(
                        (a - e).abs() < 1e-3 + 1e-3 * a.abs().max(e.abs()),
                        "{learner:?}/{variant:?} row {i} coord {j}: dense {a} vs sparse {e}"
                    );
                }
                assert_eq!(
                    dense.out_t[i], sparse.out_t[i],
                    "{learner:?}/{variant:?} row {i} out_t"
                );
            }
        }
    }
}

/// Exact equality: the sparse kernels mirror the scalar lazy-scale path of
/// `learning/` op for op, so a chained RW run through the engine is
/// bit-for-bit the `Learner::update` sequence on a `LinearModel` — on a run
/// short enough that the scale never reaches the `SCALE_FLOOR`
/// re-materialization.
#[test]
fn sparse_kernel_chain_exactly_matches_scalar_learner() {
    use golf::data::dataset::Row;
    use golf::learning::LinearModel;
    let d = 41;
    for (kind, learner) in [
        (LearnerKind::Pegasos, Learner::pegasos(0.02)),
        (LearnerKind::Adaline, Learner::adaline(0.1)),
        (LearnerKind::LogReg, Learner::logreg(0.02)),
    ] {
        let op = StepOp::for_protocol(&learner, Variant::Rw, MergeMode::Average);
        assert_eq!(op.learner, kind);
        let mut rng = Rng::new(72);
        let mut nat = NativeBackend::new();
        let mut sb = StepBatch::default();
        sb.resize_for(1, d, true);
        let mut model = LinearModel::zeros(d);
        for _ in 0..100 {
            let mut idx: Vec<u32> = (0..5).map(|_| rng.below(d as u64) as u32).collect();
            idx.sort_unstable();
            idx.dedup();
            let val: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
            let y = rng.sign();
            sb.resize_for(1, d, true); // keeps w1/s1/t1, resets the payload
            sb.push_sparse_x_row(&idx, &val);
            sb.y[0] = y;
            nat.step(&op, &mut sb).unwrap();
            sb.s1[0] = sb.out_s[0];
            sb.t1[0] = sb.out_t[0];
            learner.update(&mut model, &Row::Sparse(&idx, &val), y);
        }
        let eff: Vec<f32> = sb.w1.iter().map(|&w| w * sb.s1[0]).collect();
        assert_eq!(eff, model.weights(), "{kind:?} weights diverged");
        assert_eq!(sb.t1[0], model.t as f32, "{kind:?} counter diverged");
    }
}

/// Full-run parity on the sparse Reuters-like set: same seed, forced dense
/// vs. forced sparse path, all three learners × RW/MU/UM.  The schedules are
/// identical (dispatch touches only kernel execution), so curves must agree
/// up to f32 kernel noise on the small test set.
#[test]
fn sparse_run_matches_dense_run_all_learners_and_variants() {
    let ds = reuters_like(73, Scale(0.02));
    for learner in [Learner::pegasos(1e-2), Learner::adaline(1e-3), Learner::logreg(1e-2)] {
        for variant in [Variant::Rw, Variant::Mu, Variant::Um] {
            let mut cfg = ProtocolConfig::paper_default(6);
            cfg.learner = learner;
            cfg.variant = variant;
            cfg.eval.n_peers = 10;
            cfg.seed = 73;
            cfg.path = ExecPath::Dense;
            let a = run(cfg.clone(), &ds);
            cfg.path = ExecPath::Sparse;
            let b = run(cfg, &ds);
            assert_eq!(a.stats.sparse_rows, 0);
            assert!(b.stats.sparse_rows > 0, "sparse path did not engage");
            // identical schedules: rng-driven counters match exactly
            assert_eq!(a.stats.messages_sent, b.stats.messages_sent);
            assert_eq!(a.stats.updates_applied, b.stats.updates_applied);
            assert_eq!(a.curve.points.len(), b.curve.points.len());
            for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
                assert_eq!(pa.cycle, pb.cycle);
                assert!(
                    (pa.err_mean - pb.err_mean).abs() < 0.1,
                    "{}/{}: cycle {} dense {} vs sparse {}",
                    cfg_label(&b),
                    variant.name(),
                    pa.cycle,
                    pa.err_mean,
                    pb.err_mean
                );
            }
        }
    }
}

fn cfg_label(r: &RunResult) -> &str {
    &r.curve.label
}

// ---------------------------------------------------------------------------
// Sharded executor parity (DESIGN.md §13): partitioning the node universe
// into per-shard row ranges with cross-shard delivery lanes is a pure
// execution-strategy change — every run must be bit-for-bit identical to the
// single-queue path, for any shard count.

fn run_sharded(cfg: &ProtocolConfig, ds: &golf::data::Dataset, shards: usize) -> RunResult {
    let mut cfg = cfg.clone();
    cfg.shards = shards;
    run(cfg, ds)
}

/// shards >= 2 must reproduce shards = 1 exactly on every Table-I dataset
/// and CREATEMODEL variant.
#[test]
fn sharded_bitwise_equals_single_all_datasets_and_variants() {
    let sets = golf::experiments::datasets(81, 0.01);
    for (di, e) in sets.iter().enumerate() {
        for (vi, variant) in [Variant::Rw, Variant::Mu, Variant::Um].iter().enumerate() {
            let mut cfg = ProtocolConfig::paper_default(8);
            cfg.variant = *variant;
            cfg.eval.n_peers = 8;
            cfg.eval.voting = true;
            cfg.eval.similarity = true;
            cfg.seed = 81;
            let single = run_sharded(&cfg, &e.ds, 1);
            // rotate the shard count so the suite covers 2, 3 and 4 without
            // tripling its wall-clock
            let k = 2 + (di + vi) % 3;
            let sharded = run_sharded(&cfg, &e.ds, k);
            assert_runs_identical(
                &single,
                &sharded,
                &format!("{} {:?} shards={k}", e.ds.name, variant),
            );
        }
    }
}

/// The partition survives the paper's extreme failure scenario: churn,
/// drops, and long delays all cross shard boundaries.
#[test]
fn sharded_bitwise_equals_single_under_extreme_failures() {
    let ds = urls_like(82, Scale(0.02));
    let mut cfg = ProtocolConfig::paper_default(20).with_extreme_failures();
    cfg.eval.n_peers = 12;
    cfg.seed = 82;
    let single = run_sharded(&cfg, &ds, 1);
    for k in [2, 4] {
        let sharded = run_sharded(&cfg, &ds, k);
        assert_runs_identical(&single, &sharded, &format!("extreme failures shards={k}"));
    }
}

/// Scripted scenario timelines (drift, partitions, leaves, delay changes)
/// anchor at tick barriers, which every shard observes in lockstep.
#[test]
fn sharded_scenario_timeline_parity() {
    use golf::scenario::{
        DelaySpec, PartitionSpec, Phase, PointAction, PointEvent, Scenario,
    };
    let ds = urls_like(83, Scale(0.02));
    let mut scn = Scenario::empty("sharded-timeline");
    scn.drop = Some(0.2);
    scn.phases.push(Phase {
        name: "split".into(),
        from: 4,
        to: 12,
        drop: None,
        delay: Some(DelaySpec::Uniform(0.5, 3.0)),
        partition: Some(PartitionSpec::Halves),
        leave: Some(0.2),
    });
    scn.events.push(PointEvent { name: "invert".into(), at: 16, action: PointAction::Drift });
    scn.validate(ds.n_train(), 24).unwrap();
    let mut cfg = ProtocolConfig::paper_default(24);
    cfg.eval.n_peers = 10;
    cfg.seed = 83;
    cfg.scenario = Some(scn);
    let single = run_sharded(&cfg, &ds, 1);
    assert!(single.stats.messages_blocked > 0, "partition must engage");
    let sharded = run_sharded(&cfg, &ds, 3);
    assert_runs_identical(&single, &sharded, "scenario timeline shards=3");
}

/// Acceptance (DESIGN.md §16): graph-constrained sampling survives sharding.
/// The Topo sampler draws from per-node streams against a topology each
/// shard rebuilds identically from `(spec, n, seed)`, so shards ∈ {2, 3}
/// reproduce shards = 1 bit-for-bit on ring and Barabási–Albert graphs.
#[test]
fn sharded_topology_constrained_parity() {
    use golf::p2p::TopologySpec;
    let ds = urls_like(90, Scale(0.02));
    for spec in ["ring:2", "ba:3"] {
        let mut cfg = ProtocolConfig::paper_default(12);
        cfg.eval.n_peers = 10;
        cfg.seed = 90;
        cfg.topology = TopologySpec::parse(spec).unwrap();
        let single = run_sharded(&cfg, &ds, 1);
        let metrics = single
            .stats
            .topology
            .unwrap_or_else(|| panic!("{spec}: run stats must carry graph metrics"));
        assert_eq!(metrics.nodes, ds.n_train());
        assert_eq!(metrics.components, 1);
        for k in [2, 3] {
            let sharded = run_sharded(&cfg, &ds, k);
            assert_runs_identical(&single, &sharded, &format!("topology {spec} shards={k}"));
            assert_eq!(sharded.stats.topology, Some(metrics), "topology {spec} shards={k}");
        }
    }
}

/// Edge-level failure events anchor at tick barriers like every other
/// scenario mutation: cutting half a ring's links and repairing them later
/// stays bit-identical across shard counts — and actually blocks traffic.
#[test]
fn sharded_edge_scenario_parity() {
    use golf::p2p::TopologySpec;
    use golf::scenario::{EdgeSet, PointAction, PointEvent, Scenario};
    let ds = urls_like(91, Scale(0.02));
    let mut scn = Scenario::empty("edge-timeline");
    scn.events.push(PointEvent {
        name: "storm".into(),
        at: 3,
        action: PointAction::EdgeFail(EdgeSet::Fraction(0.5)),
    });
    scn.events.push(PointEvent {
        name: "repair".into(),
        at: 12,
        action: PointAction::EdgeRestore(None),
    });
    scn.validate(ds.n_train(), 16).unwrap();
    let mut cfg = ProtocolConfig::paper_default(16);
    cfg.eval.n_peers = 10;
    cfg.seed = 91;
    cfg.topology = TopologySpec::parse("ring:2").unwrap();
    cfg.scenario = Some(scn);
    let single = run_sharded(&cfg, &ds, 1);
    assert!(single.stats.messages_blocked > 0, "edge failures must block traffic");
    for k in [2, 3] {
        let sharded = run_sharded(&cfg, &ds, k);
        assert_runs_identical(&single, &sharded, &format!("edge scenario shards={k}"));
    }
}

/// Acceptance (DESIGN.md §17): the pairwise AUC objective threads per-model
/// example reservoirs through the sharded hot path — staged pairs, the one
/// offer draw per receive, and reservoir hand-off all follow the same
/// node-local event order as the weights, so shards ∈ {2, 3} reproduce
/// shards = 1 bit-for-bit under the extreme-failures scenario, for both
/// merge modes.  The per-cycle AUC column must populate and stay identical.
#[test]
fn sharded_pairwise_auc_parity_under_extreme_failures() {
    let ds = urls_like(92, Scale(0.02));
    for (mi, merge) in [MergeMode::Average, MergeMode::Quorum].iter().enumerate() {
        let mut cfg = ProtocolConfig::paper_default(16).with_extreme_failures();
        cfg.variant = Variant::Mu;
        cfg.learner = Learner::pairwise_auc(1e-2);
        cfg.merge = *merge;
        cfg.reservoir = 8;
        cfg.eval.n_peers = 10;
        cfg.eval.auc = true;
        cfg.seed = 92;
        let single = run_sharded(&cfg, &ds, 1);
        for p in &single.curve.points {
            let auc = p.auc.unwrap_or_else(|| panic!("{merge:?}: AUC column missing"));
            assert!((0.0..=1.0).contains(&auc), "{merge:?}: AUC {auc} out of range");
        }
        // rotate the shard count so the two merges cover 2 and 3 between
        // them without doubling the suite's wall-clock
        let k = 2 + mi;
        let sharded = run_sharded(&cfg, &ds, k);
        assert_runs_identical(&single, &sharded, &format!("pairwise {merge:?} shards={k}"));
    }
}

/// Determinism across shard counts themselves: 2, 3 and 4 shards all agree,
/// so results never encode the partition geometry.
#[test]
fn shard_count_does_not_change_results() {
    let ds = spambase_like(84, Scale(0.02));
    let mut cfg = ProtocolConfig::paper_default(10);
    cfg.variant = Variant::Um;
    cfg.eval.n_peers = 8;
    cfg.seed = 84;
    let two = run_sharded(&cfg, &ds, 2);
    let three = run_sharded(&cfg, &ds, 3);
    let four = run_sharded(&cfg, &ds, 4);
    assert_runs_identical(&two, &three, "2 vs 3 shards");
    assert_runs_identical(&two, &four, "2 vs 4 shards");
}

/// With the process-wide thread ledger drained, a sharded run degrades to
/// serial shard multiplexing on the calling thread — and must still produce
/// the same bits (the worker count is pure execution strategy too).
#[test]
fn sharded_run_identical_when_thread_budget_drained() {
    let ds = reuters_like(85, Scale(0.02));
    let mut cfg = ProtocolConfig::paper_default(8);
    cfg.eval.n_peers = 8;
    cfg.seed = 85;
    let threaded = run_sharded(&cfg, &ds, 4);
    let hold = golf::util::threads::lease(usize::MAX / 2);
    let serial = run_sharded(&cfg, &ds, 4);
    drop(hold);
    assert_runs_identical(&threaded, &serial, "drained budget vs threaded");
}

// ---------------------------------------------------------------------------
// Buffer-pool parity (DESIGN.md §14): recycling message weight buffers
// through per-shard free-lists is an allocator-level change only — pooled and
// unpooled runs must be bit-for-bit identical, and the pool must actually
// cycle buffers once the first windows have seeded its free-list.

/// pool on vs. pool off across RW/MU/UM under the extreme-failures scenario,
/// rotating the shard count so cross-shard recycle lanes are exercised too.
#[test]
fn pooled_run_bitwise_equals_unpooled_all_variants() {
    let ds = urls_like(86, Scale(0.02));
    for (vi, variant) in [Variant::Rw, Variant::Mu, Variant::Um].iter().enumerate() {
        let mut cfg = ProtocolConfig::paper_default(12).with_extreme_failures();
        cfg.variant = *variant;
        cfg.eval.n_peers = 10;
        cfg.seed = 86;
        let shards = 1 + vi; // covers 1 (local recycle only), 2 and 3
        cfg.pool = true;
        let pooled = run_sharded(&cfg, &ds, shards);
        cfg.pool = false;
        let unpooled = run_sharded(&cfg, &ds, shards);
        assert_runs_identical(
            &pooled,
            &unpooled,
            &format!("pool on/off {variant:?} shards={shards}"),
        );
        // every send requests exactly one buffer, as a hit or a miss
        assert_eq!(
            pooled.stats.pool_hits + pooled.stats.pool_misses,
            pooled.stats.messages_sent,
            "{variant:?} shards={shards}: pool counters must account for every send"
        );
        assert!(
            pooled.stats.pool_hits > 0,
            "{variant:?} shards={shards}: pool never recycled a buffer"
        );
        assert_eq!(
            unpooled.stats.pool_hits, 0,
            "{variant:?} shards={shards}: a disabled pool must never hit"
        );
    }
}

/// Scenario timelines force buffers through every fate — delivered, dropped,
/// blocked at a partition, lost to a forced-offline node — and each fate has
/// its own recycle path.  All of them must keep the run bit-identical.
#[test]
fn pooled_scenario_timeline_bitwise_equals_unpooled() {
    use golf::scenario::{
        DelaySpec, PartitionSpec, Phase, PointAction, PointEvent, Scenario,
    };
    let ds = urls_like(87, Scale(0.02));
    let mut scn = Scenario::empty("pool-timeline");
    scn.drop = Some(0.2);
    scn.phases.push(Phase {
        name: "split".into(),
        from: 4,
        to: 12,
        drop: None,
        delay: Some(DelaySpec::Uniform(0.5, 3.0)),
        partition: Some(PartitionSpec::Halves),
        leave: Some(0.2),
    });
    scn.events.push(PointEvent { name: "invert".into(), at: 16, action: PointAction::Drift });
    scn.validate(ds.n_train(), 24).unwrap();
    let mut cfg = ProtocolConfig::paper_default(24);
    cfg.eval.n_peers = 10;
    cfg.seed = 87;
    cfg.scenario = Some(scn);
    cfg.pool = true;
    let pooled = run_sharded(&cfg, &ds, 3);
    cfg.pool = false;
    let unpooled = run_sharded(&cfg, &ds, 3);
    assert!(pooled.stats.messages_blocked > 0, "partition must engage");
    assert!(pooled.stats.messages_lost_offline > 0, "leaves must engage");
    assert_runs_identical(&pooled, &unpooled, "pool on/off under scenario timeline");
    assert!(pooled.stats.pool_hits > 0, "pool never recycled a buffer");
}

// ---------------------------------------------------------------------------
// Chunked-row kernel parity (DESIGN.md §14): `NativeBackend::step` splits
// large batches into contiguous row chunks on leased threads.  Rows are
// independent by construction, so chunked execution must equal serial
// execution bit-for-bit — not approximately.

/// Dense path: run the same batch once under whatever the thread ledger
/// grants (large enough to clear both chunking thresholds) and once with the
/// ledger drained (forced serial); outputs must be identical bits.
#[test]
fn chunked_dense_step_bitwise_equals_serial() {
    use golf::engine::{PAR_MIN_WORK, PAR_ROWS_MIN};
    let mut nat = NativeBackend::new();
    let mut rng = Rng::new(88);
    let (b, d) = (4 * PAR_ROWS_MIN, 300);
    assert!(b >= 2 * PAR_ROWS_MIN && b * d >= PAR_MIN_WORK, "batch must clear thresholds");
    for learner in [LearnerKind::Pegasos, LearnerKind::Adaline, LearnerKind::LogReg] {
        for variant in [Variant::Rw, Variant::Mu, Variant::Um] {
            let op = StepOp { learner, variant, hp: 0.02, merge: MergeMode::Average };
            let base = random_batch(&mut rng, b, d);
            let mut chunked = base.clone();
            nat.step(&op, &mut chunked).unwrap();
            let hold = golf::util::threads::lease(usize::MAX / 2);
            let mut serial = base;
            nat.step(&op, &mut serial).unwrap();
            drop(hold);
            assert_eq!(chunked.out_w, serial.out_w, "{learner:?}/{variant:?} out_w");
            assert_eq!(chunked.out_t, serial.out_t, "{learner:?}/{variant:?} out_t");
        }
    }
}

/// Sparse path: same drained-vs-granted comparison over a CSR batch.  Sparse
/// results land in-place (w1 + out_s/out_t), so those are the pinned fields.
#[test]
fn chunked_sparse_step_bitwise_equals_serial() {
    use golf::engine::{PAR_MIN_WORK, PAR_ROWS_MIN};
    let mut nat = NativeBackend::new();
    let mut rng = Rng::new(89);
    let (b, d, nnz) = (4 * PAR_ROWS_MIN, 300, 12);
    assert!(b >= 2 * PAR_ROWS_MIN && b * d >= PAR_MIN_WORK, "batch must clear thresholds");
    for learner in [LearnerKind::Pegasos, LearnerKind::Adaline, LearnerKind::LogReg] {
        for variant in [Variant::Rw, Variant::Mu, Variant::Um] {
            let op = StepOp { learner, variant, hp: 0.02, merge: MergeMode::Average };
            let (_, base) = dense_and_sparse_twin(&mut rng, b, d, nnz);
            let mut chunked = base.clone();
            nat.step(&op, &mut chunked).unwrap();
            let hold = golf::util::threads::lease(usize::MAX / 2);
            let mut serial = base;
            nat.step(&op, &mut serial).unwrap();
            drop(hold);
            assert_eq!(chunked.w1, serial.w1, "{learner:?}/{variant:?} w1");
            assert_eq!(chunked.out_s, serial.out_s, "{learner:?}/{variant:?} out_s");
            assert_eq!(chunked.out_t, serial.out_t, "{learner:?}/{variant:?} out_t");
        }
    }
}

#[test]
fn cli_backend_batched_pjrt_runs() {
    if pjrt().is_none() {
        return;
    }
    let mut spec = ExperimentSpec::default();
    spec.scale = 0.005;
    spec.cycles = 4;
    spec.eval_peers = 4;
    spec.backend = golf::config::BackendChoice::BatchedPjrt;
    let ds = spec.build_dataset().unwrap();
    let cfg = spec.protocol_config().unwrap();
    let mut be = PjrtBackend::new(&PjrtBackend::default_dir()).unwrap();
    let res = run_batched(cfg, &ds, &mut be).unwrap();
    assert_eq!(res.curve.points.len(), 4);
}
