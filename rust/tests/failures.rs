//! Failure robustness (Section VI, Fig. 1 lower row): drop, delay and churn
//! — individually and combined — slow convergence but must not break it,
//! and the slowdown factors should match the paper's accounting (delay ≈ ×5,
//! drop ≈ ×2).


#![allow(deprecated)] // this suite pins the legacy shims (run/run_batched/run_deployment) bit-for-bit
use golf::data::synthetic::{urls_like, Scale};
use golf::eval::tracker::Curve;
use golf::gossip::protocol::{run, ProtocolConfig};
use golf::sim::churn::ChurnConfig;
use golf::sim::network::DelayModel;

fn base_cfg(cycles: u64, seed: u64) -> ProtocolConfig {
    let mut c = ProtocolConfig::paper_default(cycles);
    c.eval.n_peers = 25;
    c.seed = seed;
    c
}

fn auc(c: &Curve) -> f64 {
    c.points.iter().map(|p| p.err_mean).sum::<f64>() / c.points.len() as f64
}

#[test]
fn drop_only_converges() {
    let ds = urls_like(41, Scale(0.04));
    let mut cfg = base_cfg(80, 1);
    cfg.network.drop_prob = 0.5;
    let res = run(cfg, &ds);
    assert!(res.stats.messages_dropped > 0);
    assert!(res.curve.final_error() < 0.16, "final {}", res.curve.final_error());
}

#[test]
fn delay_only_converges() {
    let ds = urls_like(42, Scale(0.04));
    let mut cfg = base_cfg(80, 2);
    cfg.network.delay = DelayModel::Uniform { lo: cfg.delta, hi: 10 * cfg.delta };
    let res = run(cfg, &ds);
    assert!(res.curve.final_error() < 0.16, "final {}", res.curve.final_error());
}

#[test]
fn churn_only_converges() {
    let ds = urls_like(43, Scale(0.04));
    let mut cfg = base_cfg(80, 3);
    cfg.churn = Some(ChurnConfig::paper_default(cfg.delta));
    let res = run(cfg, &ds);
    assert!(res.stats.messages_lost_offline > 0 || res.curve.final_error() < 0.2);
    assert!(res.curve.final_error() < 0.16, "final {}", res.curve.final_error());
}

#[test]
fn all_failures_converge_slower_but_converge() {
    let ds = urls_like(44, Scale(0.04));
    let clean = run(base_cfg(80, 4), &ds);
    let failed = run(base_cfg(80, 4).with_extreme_failures(), &ds);
    // slower...
    assert!(
        auc(&failed.curve) >= auc(&clean.curve) - 0.01,
        "failures can't speed things up: {} vs {}",
        auc(&failed.curve),
        auc(&clean.curve)
    );
    // ...but still converging
    let first = failed.curve.points.first().unwrap().err_mean;
    assert!(failed.curve.final_error() < first);
}

#[test]
fn delay_shifts_convergence_right() {
    // the paper attributes most of the slowdown to delay: messages wait ~5
    // cycles on average, so reaching a given error takes ~5x the cycles
    let ds = urls_like(45, Scale(0.04));
    let clean = run(base_cfg(120, 5), &ds);
    let mut cfg = base_cfg(120, 5);
    cfg.network.delay = DelayModel::Uniform { lo: cfg.delta, hi: 10 * cfg.delta };
    let delayed = run(cfg, &ds);
    let thr = 0.15;
    if let (Some(a), Some(b)) =
        (clean.curve.cycles_to_reach(thr), delayed.curve.cycles_to_reach(thr))
    {
        assert!(
            b as f64 >= 1.5 * a as f64,
            "delay should slow convergence: clean {a} vs delayed {b}"
        );
    } else {
        panic!("both runs should reach {thr}");
    }
}

#[test]
fn message_loss_accounting_consistent() {
    let ds = urls_like(46, Scale(0.03));
    let cfg = base_cfg(40, 6).with_extreme_failures();
    let res = run(cfg, &ds);
    let s = &res.stats;
    assert!(s.messages_dropped + s.messages_lost_offline < s.messages_sent);
    assert!(s.updates_applied <= s.messages_sent - s.messages_dropped - s.messages_lost_offline);
    // MU applies exactly one update per delivered message
    assert_eq!(s.updates_applied, s.messages_delivered);
    // regression: with [Δ, 10Δ] delays, last-cycle sends are still in flight
    // at the horizon and must not be counted as delivered
    assert!(
        s.messages_delivered < s.messages_sent - s.messages_dropped - s.messages_lost_offline,
        "in-flight messages counted as delivered"
    );
    // drop rate near the configured 0.5
    let rate = s.messages_dropped as f64 / s.messages_sent as f64;
    assert!((rate - 0.5).abs() < 0.05, "drop rate {rate}");
}
