//! Protocol-level integration: cost model, determinism, sampler variants,
//! local voting (Fig. 3 shape), and the UM-vs-MU relationship (Fig. 2).


#![allow(deprecated)] // this suite pins the legacy shims (run/run_batched/run_deployment) bit-for-bit
use golf::data::synthetic::{urls_like, Scale};
use golf::eval::tracker::Curve;
use golf::gossip::create_model::Variant;
use golf::gossip::protocol::{run, ProtocolConfig};
use golf::p2p::overlay::SamplerConfig;

fn cfg(cycles: u64, seed: u64) -> ProtocolConfig {
    let mut c = ProtocolConfig::paper_default(cycles);
    c.eval.n_peers = 25;
    c.seed = seed;
    c
}

fn auc(c: &Curve) -> f64 {
    c.points.iter().map(|p| p.err_mean).sum::<f64>() / c.points.len() as f64
}

#[test]
fn cost_model_one_message_per_node_per_cycle() {
    let ds = urls_like(51, Scale(0.03));
    let n = ds.n_train() as f64;
    let res = run(cfg(25, 1), &ds);
    let per = res.stats.messages_sent as f64 / (n * 25.0);
    assert!((per - 1.0).abs() < 0.05, "messages per node-cycle {per}");
    // message size: full frame = 27-byte overhead + d*4 weights + view
    // bytes (NEWSCAST payload = own descriptor + up to 20 view entries)
    let bytes_per_msg = res.stats.bytes_sent as f64 / res.stats.messages_sent as f64;
    let d = ds.d() as f64;
    assert!(bytes_per_msg >= 27.0 + d * 4.0);
    assert!(bytes_per_msg <= 27.0 + d * 4.0 + 21.0 * 16.0);
}

#[test]
fn newscast_close_to_oracle_sampling() {
    // the paper's assumption: NEWSCAST behaves like uniform peer sampling
    let ds = urls_like(52, Scale(0.04));
    let mut a = cfg(50, 2);
    a.sampler = SamplerConfig::Newscast { view_size: 20 };
    let mut b = cfg(50, 2);
    b.sampler = SamplerConfig::Oracle;
    let ra = run(a, &ds);
    let rb = run(b, &ds);
    assert!(
        (auc(&ra.curve) - auc(&rb.curve)).abs() < 0.05,
        "newscast {} vs oracle {}",
        auc(&ra.curve),
        auc(&rb.curve)
    );
}

#[test]
fn um_not_faster_than_mu() {
    // Section V-B + Fig 2: MU maintains more model independence and
    // converges at least as fast as UM
    let ds = urls_like(53, Scale(0.04));
    let mut mu_cfg = cfg(60, 3);
    mu_cfg.variant = Variant::Mu;
    let mut um_cfg = cfg(60, 3);
    um_cfg.variant = Variant::Um;
    let mu = run(mu_cfg, &ds);
    let um = run(um_cfg, &ds);
    assert!(
        auc(&mu.curve) <= auc(&um.curve) + 0.02,
        "mu {} vs um {}",
        auc(&mu.curve),
        auc(&um.curve)
    );
}

#[test]
fn voting_helps_rw_significantly() {
    // Fig 3: voting gives a large improvement for the no-merge variant
    let ds = urls_like(54, Scale(0.04));
    let mut c = cfg(60, 4);
    c.variant = Variant::Rw;
    c.eval.voting = true;
    let res = run(c, &ds);
    // compare freshest vs voted over the later half of the curve
    let pts = &res.curve.points;
    let half = pts.len() / 2;
    let fresh: f64 =
        pts[half..].iter().map(|p| p.err_mean).sum::<f64>() / (pts.len() - half) as f64;
    let vote: f64 = pts[half..]
        .iter()
        .map(|p| p.err_vote.unwrap())
        .sum::<f64>()
        / (pts.len() - half) as f64;
    assert!(vote <= fresh + 0.01, "vote {vote} vs freshest {fresh}");
}

#[test]
fn similarity_rises_as_models_converge() {
    let ds = urls_like(55, Scale(0.03));
    let mut c = cfg(50, 5);
    c.eval.similarity = true;
    let res = run(c, &ds);
    let sims: Vec<f64> =
        res.curve.points.iter().map(|p| p.similarity.unwrap()).collect();
    assert!(
        sims.last().unwrap() > sims.first().unwrap(),
        "{sims:?}"
    );
    assert!(sims.iter().all(|s| (-1.0..=1.0).contains(s)));
}

#[test]
fn full_run_bit_deterministic() {
    let ds = urls_like(56, Scale(0.03));
    let mut a = cfg(30, 6).with_extreme_failures();
    a.eval.voting = true;
    a.eval.similarity = true;
    let mut b = a.clone();
    b.seed = a.seed;
    let ra = run(a, &ds);
    let rb = run(b, &ds);
    for (pa, pb) in ra.curve.points.iter().zip(&rb.curve.points) {
        assert_eq!(pa.err_mean, pb.err_mean);
        assert_eq!(pa.err_vote, pb.err_vote);
        assert_eq!(pa.similarity, pb.similarity);
    }
    assert_eq!(ra.stats.messages_sent, rb.stats.messages_sent);
    assert_eq!(ra.stats.messages_dropped, rb.stats.messages_dropped);
}

#[test]
fn different_seeds_differ() {
    let ds = urls_like(57, Scale(0.03));
    let ra = run(cfg(20, 7), &ds);
    let rb = run(cfg(20, 8), &ds);
    assert_ne!(
        ra.curve.points.last().unwrap().err_mean,
        rb.curve.points.last().unwrap().err_mean
    );
}
