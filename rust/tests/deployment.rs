//! Deployment-runtime integration tests (DESIGN.md §10): a real ≥64-node
//! localhost-TCP deployment with NEWSCAST sampling and churn injection must
//! produce a convergence curve on the same axes as — and within tolerance
//! of — a matched-config simulator run.
//!
//! These tests open hundreds of sockets and time gossip on the wall clock,
//! so they serialize through one mutex (and CI additionally runs this
//! binary with `--test-threads=1`) to avoid contending for ports and CPU.


#![allow(deprecated)] // this suite pins the legacy shims (run/run_batched/run_deployment) bit-for-bit
use golf::coordinator::{matched_sim_config, run_deployment};
use golf::data::synthetic::{urls_like, Scale};
use golf::gossip::protocol::run;
use golf::net::deploy::DeployConfig;
use golf::p2p::overlay::SamplerConfig;
use std::sync::Mutex;
use std::time::{Duration, Instant};

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// The acceptance test: 80 real nodes, NEWSCAST peer sampling over the
/// wire, churn injected from the simulator's schedule — and the resulting
/// curve comparable point-for-point with a matched `GossipSim` run.
#[test]
fn deploy_parity_with_matched_simulator() {
    let _g = serial();
    let ds = urls_like(5, Scale(0.008)); // 80 training rows -> 80 nodes
    let mut cfg = DeployConfig {
        n_nodes: ds.n_train(),
        delta: Duration::from_millis(40),
        cycles: 40,
        sampler: SamplerConfig::Newscast { view_size: 20 },
        eval_peers: 20,
        seed: 7,
        ..Default::default()
    };
    // churn only: the paper's schedule at 90% online.  (Drop/delay are
    // exercised by deploy_under_extreme_failures_smoke; keeping them off
    // here keeps the wall-clock run tight enough for a sharp tolerance.)
    cfg.churn = Some(golf::sim::churn::ChurnConfig::paper_default(
        golf::net::deploy::SIM_DELTA,
    ));
    assert!(cfg.n_nodes >= 64, "acceptance requires a 64+ node deployment");

    let report = run_deployment(&cfg, &ds).expect("deployment failed");
    let sim = run(matched_sim_config(&cfg), &ds);

    // same measurement grid: the curves share their x axis
    let deploy_cycles: Vec<u64> = report.curve.points.iter().map(|p| p.cycle).collect();
    let sim_cycles: Vec<u64> = sim.curve.points.iter().map(|p| p.cycle).collect();
    assert_eq!(deploy_cycles, sim_cycles, "curves must share the cycle grid");

    // the deployment really gossiped
    assert!(report.stats.messages_received > cfg.n_nodes as u64);
    assert!(report.mean_model_t > 1.0, "models never updated");

    // curve shape: converging from the zero-model plateau
    let first = report.curve.points.first().unwrap().err_mean;
    let last = report.curve.final_error();
    assert!(last < first - 0.05, "deployment must converge: {first} -> {last}");

    // final-error parity with the matched simulator run
    let gap = (last - sim.curve.final_error()).abs();
    assert!(
        gap < 0.15,
        "deploy {last:.4} vs sim {:.4}: gap {gap:.4} out of tolerance",
        sim.curve.final_error()
    );
}

/// Topology acceptance (DESIGN.md §16): one non-complete graph constrains
/// both a real 80-node socket deployment and the matched simulator run —
/// NEWSCAST views filtered to graph neighbors on the wire — and the final
/// errors still agree within the standard parity tolerance.
#[test]
fn deploy_topology_constrained_parity_with_sim() {
    use golf::p2p::TopologySpec;
    let _g = serial();
    let ds = urls_like(5, Scale(0.008)); // 80 training rows -> 80 nodes
    let cfg = DeployConfig {
        n_nodes: ds.n_train(),
        delta: Duration::from_millis(40),
        cycles: 40,
        sampler: SamplerConfig::Newscast { view_size: 20 },
        eval_peers: 20,
        seed: 21,
        topology: TopologySpec::parse("kreg:4").unwrap(),
        ..Default::default()
    };
    assert!(cfg.n_nodes >= 64, "acceptance requires a 64+ node deployment");

    let report = run_deployment(&cfg, &ds).expect("deployment failed");
    let sim = run(matched_sim_config(&cfg), &ds);

    // the matched sim run carries the graph it was constrained by
    let m = sim.stats.topology.expect("sim stats must carry graph metrics");
    assert_eq!(m.nodes, 80);
    assert_eq!(m.degree_max, 4, "kreg:4 is exactly 4-regular");
    assert_eq!(m.components, 1);

    // same measurement grid: the curves share their x axis
    let deploy_cycles: Vec<u64> = report.curve.points.iter().map(|p| p.cycle).collect();
    let sim_cycles: Vec<u64> = sim.curve.points.iter().map(|p| p.cycle).collect();
    assert_eq!(deploy_cycles, sim_cycles, "curves must share the cycle grid");

    // the deployment really gossiped under the degree-4 constraint
    assert!(report.stats.messages_received > cfg.n_nodes as u64);
    assert!(report.mean_model_t > 1.0, "models never updated");

    // still converging from the zero-model plateau despite the sparse graph
    let first = report.curve.points.first().unwrap().err_mean;
    let last = report.curve.final_error();
    assert!(last < first - 0.05, "deployment must converge: {first} -> {last}");

    // final-error parity with the matched, equally constrained sim run
    let gap = (last - sim.curve.final_error()).abs();
    assert!(
        gap < 0.15,
        "deploy {last:.4} vs sim {:.4}: gap {gap:.4} out of tolerance",
        sim.curve.final_error()
    );
}

/// Scenario parity (DESIGN.md §11): one partition-heal timeline drives a
/// 64-node socket deployment and a matched `GossipSim` run from the same
/// definition; the curves share their grid, the partition blocks real
/// traffic in both, and final errors agree within the PR 3 tolerance.
#[test]
fn deploy_partition_heal_scenario_parity_with_sim() {
    use golf::scenario::{PartitionSpec, Phase, Scenario};
    let _g = serial();
    let ds = urls_like(7, Scale(0.0064)); // 64 training rows -> 64 nodes
    let mut scn = Scenario::empty("partition-heal-small");
    scn.phases.push(Phase {
        name: "split".into(),
        from: 8,
        to: 22,
        drop: None,
        delay: None,
        partition: Some(PartitionSpec::Halves),
        leave: None,
    });
    scn.validate(ds.n_train(), 40).unwrap();
    let cfg = DeployConfig {
        n_nodes: ds.n_train(),
        delta: Duration::from_millis(40),
        cycles: 40,
        sampler: SamplerConfig::Newscast { view_size: 20 },
        eval_peers: 20,
        seed: 13,
        scenario: Some(scn),
        ..Default::default()
    };

    let report = run_deployment(&cfg, &ds).expect("deployment failed");
    let sim = run(matched_sim_config(&cfg), &ds);

    // one shared definition: the simulator's compiled timeline blocked
    // messages and so did the real sockets
    assert!(sim.stats.messages_blocked > 0, "sim partition must engage");
    assert!(
        report.stats.partition_blocked > 0,
        "deployment partition must engage"
    );

    // same measurement grid
    let deploy_cycles: Vec<u64> = report.curve.points.iter().map(|p| p.cycle).collect();
    let sim_cycles: Vec<u64> = sim.curve.points.iter().map(|p| p.cycle).collect();
    assert_eq!(deploy_cycles, sim_cycles, "curves must share the cycle grid");

    // both converge after the heal, and land within the parity tolerance
    let first = report.curve.points.first().unwrap().err_mean;
    let last = report.curve.final_error();
    assert!(last < first - 0.05, "deployment must converge: {first} -> {last}");
    let gap = (last - sim.curve.final_error()).abs();
    assert!(
        gap < 0.15,
        "deploy {last:.4} vs sim {:.4}: gap {gap:.4} out of tolerance",
        sim.curve.final_error()
    );
}

/// Smoke test under the full Section VI-A(i) failure set: 64 nodes with
/// 50% drop, [Δ,10Δ] delay, and churn, all injected on the wall clock.
#[test]
fn deploy_under_extreme_failures_smoke() {
    let _g = serial();
    let ds = urls_like(6, Scale(0.0064)); // 64 training rows
    let cfg = DeployConfig {
        n_nodes: ds.n_train(),
        delta: Duration::from_millis(25),
        cycles: 16,
        eval_peers: 12,
        seed: 11,
        ..Default::default()
    }
    .with_extreme_failures();
    assert_eq!(cfg.n_nodes, 64);

    let report = run_deployment(&cfg, &ds).expect("deployment failed");
    let s = &report.stats;
    assert!(s.messages_sent > 0);
    assert!(s.sim_dropped > 0, "the 50% drop model must engage");
    assert!(s.messages_received > 0, "some messages must still get through");
    // delivered + injected losses never exceed what was sent (delayed
    // messages still in flight at shutdown are simply lost)
    assert!(s.messages_received + s.sim_dropped + s.backlog_lost <= s.messages_sent);
    assert!(
        !report.curve.points.is_empty(),
        "failure injection must not stall the evaluation loop"
    );
    assert!(report.final_error <= 0.5, "error {}", report.final_error);
}

/// De-flaked successor of the old `tcp_deployment_learns`: a short run must
/// show a learning signal, but the absolute-error bar is generous and the
/// primary assertions are relative, so a slow CI machine that processes
/// fewer wall-clock cycles still passes.
#[test]
fn deploy_short_run_learns() {
    let _g = serial();
    let ds = urls_like(5, Scale(0.0024)); // 24 training rows
    let cfg = DeployConfig {
        n_nodes: ds.n_train(),
        delta: Duration::from_millis(25),
        cycles: 30,
        eval_peers: 12,
        seed: 3,
        ..Default::default()
    };
    let report = run_deployment(&cfg, &ds).expect("deployment failed");
    assert!(report.stats.messages_sent > cfg.n_nodes as u64);
    assert!(report.stats.messages_received > 0, "received 0");
    assert!(report.mean_model_t > 1.0, "models never updated");
    let first = report.curve.points.first().unwrap().err_mean;
    let last = report.curve.final_error();
    // relative: never worse than the start; absolute: strictly below the
    // ~0.33 predict-all-negative plateau, with slack for loaded machines
    assert!(last <= first + 1e-9, "error rose: {first} -> {last}");
    assert!(last < 0.32, "no learning signal: final error {last}");
}

/// Node-group scale smoke (DESIGN.md §15): 1000 real nodes — double the
/// retired thread-per-node cap of 512 — multiplexed onto four group
/// threads, time-bounded so CI catches a runtime that stalls or degrades
/// to per-node threading.  Also pins the group-runtime observability:
/// the group count lands in `DeployStats`, the readiness loops decode
/// frames, and the LRU outbound cache produces connection reuse.
#[test]
fn deploy_thousand_nodes_in_four_groups() {
    let _g = serial();
    let ds = urls_like(8, Scale(0.1)); // 1000 training rows -> 1000 nodes
    let cfg = DeployConfig {
        n_nodes: ds.n_train(),
        node_groups: 4,
        delta: Duration::from_millis(60),
        cycles: 5,
        eval_peers: 8,
        eval_at_cycles: vec![5],
        seed: 17,
        ..Default::default()
    };
    assert_eq!(cfg.n_nodes, 1000);
    assert!(cfg.n_nodes > 512, "must exceed the retired thread-per-node cap");

    let t0 = Instant::now();
    let report = run_deployment(&cfg, &ds).expect("deployment failed");
    let elapsed = t0.elapsed();
    // generous wall bound: the run itself is ~0.4 s of gossip; anything
    // near a minute means the runtime fell over at this scale
    assert!(elapsed < Duration::from_secs(60), "1k-node run took {elapsed:?}");

    let s = &report.stats;
    // the thread ledger may grant fewer groups on a small machine, but the
    // runtime must stay within the ask and never fall back to per-node
    // threads
    assert!(
        (1..=4).contains(&s.node_groups),
        "groups {} outside the leased range",
        s.node_groups
    );
    assert_eq!(report.per_node.len(), 1000);
    assert!(s.messages_sent > 1000, "every node gossips at least once");
    assert!(s.messages_received > 0, "frames must flow through the groups");
    assert!(
        s.conns_reused > 0,
        "repeat sends must ride the LRU outbound cache"
    );
    assert!(s.frames_per_wake > 0.0, "readiness loops must decode frames");
    assert!(!report.curve.points.is_empty());
}

/// `golf deploy` end to end through the CLI: tiny run, `--compare-sim`,
/// CSV output.
#[test]
fn deploy_cli_end_to_end() {
    let _g = serial();
    // 0.002 scale -> 20 urls nodes; a handful of 10 ms cycles keeps the
    // socket run well under a second
    let out = std::env::temp_dir().join("golf_cli_deployment_test.csv");
    let args: Vec<String> = [
        "deploy", "--dataset", "urls", "--scale", "0.002", "--cycles", "4",
        "--delta_ms", "10", "--eval_peers", "6", "--compare-sim",
        "--out", out.to_str().unwrap(),
    ]
    .iter()
    .map(|a| a.to_string())
    .collect();
    assert_eq!(golf::cli::dispatch(&args), 0);
    assert!(out.exists());
    std::fs::remove_file(&out).ok();
}

/// Shutdown is prompt: the coordinator stops after the last measurement
/// cycle and every node thread exits on the stop flag.
#[test]
fn deploy_respects_stop_flag_quickly() {
    let _g = serial();
    let ds = urls_like(6, Scale(0.001)); // tiny: 10 nodes
    let cfg = DeployConfig {
        n_nodes: ds.n_train(),
        delta: Duration::from_millis(15),
        cycles: 8,
        eval_peers: 5,
        ..Default::default()
    };
    let t0 = Instant::now();
    let report = run_deployment(&cfg, &ds).expect("deployment failed");
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "run took {:?}",
        t0.elapsed()
    );
    assert_eq!(report.per_node.len(), cfg.n_nodes);
}

/// Observer streaming from the deployment target (api facade acceptance):
/// the eval-point events match the returned curve exactly, one NodeStats
/// event arrives per node, and observation does not disturb the run.
#[test]
fn deploy_observer_streams_eval_points_and_node_stats() {
    use golf::api::{CurveRecorder, RunSpec};
    let _g = serial();
    let mut rec = CurveRecorder::new();
    let outcome = RunSpec::new("urls")
        .scale(0.0012) // 12 nodes
        .cycles(5)
        .eval_peers(5)
        .seed(9)
        .deploy(12, 0) // 12 ms wall-clock Δ, one node per training row
        .build()
        .expect("deploy spec valid")
        .run(&mut rec)
        .expect("deployment run");
    let report = outcome.deploy_report().expect("deploy outcome");

    // streamed eval points == returned curve, point for point
    let streamed = rec.eval_points();
    assert_eq!(streamed.len(), report.curve.points.len());
    for (s, p) in streamed.iter().zip(&report.curve.points) {
        assert_eq!(s.cycle, p.cycle);
        assert_eq!(s.err_mean, p.err_mean);
        assert_eq!(s.messages_sent, p.messages_sent);
    }
    // one NodeStats event per node, in node order, agreeing with per_node
    let stats = rec.node_stats();
    assert_eq!(stats.len(), report.per_node.len());
    for (i, (node, sent, received)) in stats.iter().enumerate() {
        assert_eq!(*node, i);
        assert_eq!(*sent, report.per_node[i].sent);
        assert_eq!(*received, report.per_node[i].received);
    }
    // cycle boundaries cover the measurement grid
    assert_eq!(rec.cycles().len(), report.curve.points.len());
}
