//! Scenario-engine integration tests (DESIGN.md §11): the declarative
//! timelines must drive the event-driven simulator and the cycle-synchronous
//! batched engine from one shared definition, the `paper-fig3` built-in must
//! reproduce the hand-wired extreme-failure configuration bit-for-bit, and
//! scenario sweep grids must be thread-count independent.


#![allow(deprecated)] // this suite pins the legacy shims (run/run_batched/run_deployment) bit-for-bit
use golf::data::synthetic::{urls_like, Scale};
use golf::engine::batched::run_batched;
use golf::engine::native::NativeBackend;
use golf::experiments::sweep;
use golf::gossip::create_model::Variant;
use golf::gossip::protocol::{run, ExecMode, ProtocolConfig, RunResult};
use golf::learning::Learner;
use golf::scenario::{
    builtin, ChurnSpec, DelaySpec, Membership, PartitionSpec, Phase, PointAction, PointEvent,
    Scenario, TraceEntry,
};

fn assert_runs_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.curve.points.len(), b.curve.points.len(), "{what}: point counts");
    for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
        assert_eq!(pa.cycle, pb.cycle, "{what}");
        assert_eq!(pa.err_mean, pb.err_mean, "{what} @ cycle {}", pa.cycle);
        assert_eq!(pa.err_std, pb.err_std, "{what} @ cycle {}", pa.cycle);
        assert_eq!(pa.auc, pb.auc, "{what} @ cycle {}", pa.cycle);
        assert_eq!(pa.messages_sent, pb.messages_sent, "{what} @ cycle {}", pa.cycle);
    }
    assert_eq!(a.stats.messages_sent, b.stats.messages_sent, "{what}");
    assert_eq!(a.stats.messages_dropped, b.stats.messages_dropped, "{what}");
    assert_eq!(a.stats.messages_blocked, b.stats.messages_blocked, "{what}");
    assert_eq!(a.stats.messages_lost_offline, b.stats.messages_lost_offline, "{what}");
    assert_eq!(a.stats.messages_delivered, b.stats.messages_delivered, "{what}");
    assert_eq!(a.stats.updates_applied, b.stats.updates_applied, "{what}");
}

/// Acceptance: the `paper-fig3` built-in reproduces the hand-wired
/// `with_extreme_failures()` run bit-for-bit — same churn schedule, same
/// drop/delay draws, same curve — in scalar mode and micro-batched mode.
#[test]
fn paper_fig3_scenario_bitwise_matches_extreme_failures() {
    let ds = urls_like(31, Scale(0.02));
    for exec in [ExecMode::Scalar, ExecMode::MicroBatch { coalesce: 0 }] {
        let mut base = ProtocolConfig::paper_default(40).with_extreme_failures();
        base.eval.n_peers = 15;
        base.seed = 31;
        base.exec = exec;
        let mut scripted = ProtocolConfig::paper_default(40);
        scripted.eval.n_peers = 15;
        scripted.seed = 31;
        scripted.exec = exec;
        scripted.scenario = Some(builtin("paper-fig3").unwrap());
        let a = run(base, &ds);
        let b = run(scripted, &ds);
        assert!(b.stats.messages_dropped > 0, "the scripted drop model must engage");
        assert_runs_identical(&a, &b, &format!("fig3 vs scenario ({})", exec.name()));
    }
}

/// A partition blocks cross-component gossip; after healing the network
/// converges again.  Same definition through both execution engines.
#[test]
fn partition_heal_blocks_then_reconverges() {
    let ds = urls_like(32, Scale(0.005)); // 50 nodes
    let scn = builtin("partition-heal").unwrap();
    let cycles = scn.cycles_hint.unwrap();
    let mut cfg = ProtocolConfig::paper_default(cycles);
    cfg.eval.n_peers = 15;
    cfg.seed = 32;
    cfg.scenario = Some(scn.clone());
    let res = run(cfg.clone(), &ds);
    assert!(res.stats.messages_blocked > 0, "the split must block messages");
    // accounting stays exact under block/heal transitions
    assert!(
        res.stats.messages_delivered
            + res.stats.messages_dropped
            + res.stats.messages_blocked
            + res.stats.messages_lost_offline
            <= res.stats.messages_sent
    );
    let first = res.curve.points.first().unwrap().err_mean;
    let last = res.curve.final_error();
    assert!(last < first && last < 0.25, "post-heal convergence: {first} -> {last}");
    // the same scenario drives the cycle-synchronous engine
    let mut be = NativeBackend::new();
    let batched = run_batched(cfg, &ds, &mut be).unwrap();
    assert!(batched.stats.messages_blocked > 0);
    assert!(batched.curve.final_error() < first);
}

/// Concept drift re-labels the stream: the error measured against the
/// current concept spikes at the drift and then recovers as models re-learn.
#[test]
fn drift_spikes_error_then_recovers() {
    let ds = urls_like(33, Scale(0.005));
    let mut scn = Scenario::empty("drift-test");
    scn.events.push(PointEvent {
        name: "invert".into(),
        at: 30,
        action: PointAction::Drift,
    });
    let mut cfg = ProtocolConfig::paper_default(90);
    cfg.eval.n_peers = 15;
    cfg.seed = 33;
    cfg.eval.at_cycles = (1..=90).step_by(3).collect();
    cfg.scenario = Some(scn);
    let res = run(cfg, &ds);
    let err_at = |c: u64| {
        res.curve
            .points
            .iter()
            .find(|p| p.cycle == c)
            .unwrap_or_else(|| panic!("no point at cycle {c}"))
            .err_mean
    };
    let before = err_at(28);
    let after = err_at(34);
    let final_err = res.curve.final_error();
    assert!(
        after > before + 0.2,
        "drift must spike the error: {before} -> {after}"
    );
    assert!(
        final_err < after - 0.2,
        "models must re-learn the inverted concept: {after} -> {final_err}"
    );
}

/// Flash crowd: a run that starts at half membership and doubles at cycle 10
/// sends measurably more traffic than one that stays at half, and the grown
/// nodes integrate (the run still converges).
#[test]
fn flash_crowd_grows_membership_and_traffic() {
    let ds = urls_like(34, Scale(0.004)); // 40-node universe
    let mut scn = Scenario::empty("crowd");
    scn.initial = Some(Membership::Fraction(0.5));
    scn.events.push(PointEvent {
        name: "crowd".into(),
        at: 10,
        action: PointAction::Join(Membership::Fraction(1.0)),
    });
    let mut cfg = ProtocolConfig::paper_default(30);
    cfg.eval.n_peers = 10;
    cfg.seed = 34;
    cfg.scenario = Some(scn);
    let grown = run(cfg.clone(), &ds);

    let mut half = Scenario::empty("half");
    half.initial = Some(Membership::Fraction(0.5));
    cfg.scenario = Some(half);
    let stayed = run(cfg, &ds);

    // ~20 nodes * 30 cycles vs 20*10 + 40*20: a clear margin, loosely bound
    assert!(
        grown.stats.messages_sent as f64 > stayed.stats.messages_sent as f64 * 1.3,
        "grown {} vs stayed {}",
        grown.stats.messages_sent,
        stayed.stats.messages_sent
    );
    let first = grown.curve.points.first().unwrap().err_mean;
    assert!(grown.curve.final_error() < first, "flash crowd must still converge");
}

/// Pairwise AUC gossip (DESIGN.md §17) through the `partition-heal`
/// built-in: the split (cycles 40–120) blocks cross-half walks, but each
/// half keeps training on its own reservoir pairs, and once the partition
/// heals the AUC curve recovers to the unpartitioned regime.
#[test]
fn pairwise_auc_survives_partition_heal() {
    let ds = urls_like(40, Scale(0.005)); // 50 nodes
    let scn = builtin("partition-heal").unwrap();
    let cycles = scn.cycles_hint.unwrap();
    let mut cfg = ProtocolConfig::paper_default(cycles);
    cfg.learner = Learner::pairwise_auc(1e-2);
    cfg.reservoir = 8;
    cfg.eval.auc = true;
    cfg.eval.n_peers = 15;
    cfg.eval.at_cycles = vec![1, 40, 80, 120, 160, cycles];
    cfg.seed = 40;
    cfg.scenario = Some(scn);
    let res = run(cfg, &ds);
    assert!(res.stats.messages_blocked > 0, "the split must block messages");
    let auc_at = |c: u64| {
        res.curve
            .points
            .iter()
            .find(|p| p.cycle == c)
            .unwrap_or_else(|| panic!("no point at cycle {c}"))
            .auc
            .unwrap_or_else(|| panic!("no AUC at cycle {c}"))
    };
    let (start, mid_split, at_heal, healed) =
        (auc_at(1), auc_at(80), auc_at(120), auc_at(cycles));
    assert!(mid_split > 0.5, "halves must keep ranking mid-split: {mid_split}");
    assert!(healed > start, "AUC must rise over the run: {start} -> {healed}");
    assert!(healed > 0.7, "post-heal AUC too low: {healed}");
    assert!(
        healed > at_heal - 0.05,
        "healing must not collapse the ranking: {at_heal} -> {healed}"
    );
}

/// Pairwise AUC gossip through the `flash-crowd` built-in: reservoirs are
/// seeded per node from the run seed, so a join wave that quadruples
/// membership mid-run stays fully deterministic — two identical runs agree
/// bit-for-bit on every curve column, AUC included — and the grown crowd
/// still learns to rank.
#[test]
fn pairwise_auc_deterministic_through_flash_crowd() {
    let ds = urls_like(41, Scale(0.004)); // 40-node universe
    let scn = builtin("flash-crowd").unwrap();
    let cycles = scn.cycles_hint.unwrap();
    let mut cfg = ProtocolConfig::paper_default(cycles);
    cfg.learner = Learner::pairwise_auc(1e-2);
    cfg.reservoir = 8;
    cfg.eval.auc = true;
    cfg.eval.n_peers = 10;
    cfg.eval.at_cycles = vec![1, 50, 100, 150, cycles];
    cfg.seed = 41;
    cfg.scenario = Some(scn);
    let a = run(cfg.clone(), &ds);
    let b = run(cfg, &ds);
    assert_runs_identical(&a, &b, "flash-crowd pairwise replay");
    let last = a.curve.points.last().unwrap();
    let auc = last.auc.expect("AUC column must populate");
    assert!(auc > 0.7, "post-crowd AUC too low: {auc}");
    assert!(
        a.curve.points.iter().all(|p| p.auc.is_some()),
        "every eval point must carry an AUC"
    );
}

/// A mass-leave phase forces nodes offline (messages to them are lost) and
/// restores them when the phase ends.
#[test]
fn mass_leave_phase_pauses_and_restores() {
    let ds = urls_like(35, Scale(0.004));
    let mut scn = Scenario::empty("outage");
    scn.phases.push(Phase {
        name: "out".into(),
        from: 5,
        to: 15,
        drop: None,
        delay: None,
        partition: None,
        leave: Some(0.5),
    });
    let mut cfg = ProtocolConfig::paper_default(40);
    cfg.eval.n_peers = 10;
    cfg.seed = 35;
    cfg.scenario = Some(scn);
    let res = run(cfg, &ds);
    assert!(
        res.stats.messages_lost_offline > 0,
        "messages to forced-offline nodes must be lost"
    );
    let first = res.curve.points.first().unwrap().err_mean;
    let last = res.curve.final_error();
    assert!(last < first && last < 0.25, "{first} -> {last}");
}

/// Replayed availability traces drive churn: nodes go down exactly in their
/// scripted windows, and messages addressed to them during an outage are
/// lost offline.
#[test]
fn trace_replay_controls_availability() {
    let ds = urls_like(36, Scale(0.002)); // 20 nodes >= the 16 traced
    let scn = builtin("trace-replay").unwrap();
    let cycles = scn.cycles_hint.unwrap();
    let mut cfg = ProtocolConfig::paper_default(cycles);
    cfg.eval.n_peers = 8;
    cfg.seed = 36;
    cfg.scenario = Some(scn.clone());
    let res = run(cfg, &ds);
    assert!(
        res.stats.messages_lost_offline > 0,
        "traced outages must lose some deliveries"
    );
    assert!(!res.curve.points.is_empty());
    // the trace windows really are what the schedule replays
    if let Some(ChurnSpec::Trace(entries)) = &scn.churn {
        let sched = golf::scenario::driver::trace_schedule(entries, 20, 1000, cycles * 1000);
        assert!(sched.is_online(0, 10_000)); // cycle 10: first window
        assert!(!sched.is_online(0, 70_000)); // cycle 70: between windows
        assert!(sched.is_online(0, 150_000)); // cycle 150: second window
        assert!(sched.is_online(19, 70_000), "untraced nodes stay online");
    } else {
        panic!("trace-replay must carry a trace churn spec");
    }
}

/// The delay-spike built-in runs end to end and still converges (delays are
/// reordering, not loss).
#[test]
fn delay_spike_builtin_converges() {
    let ds = urls_like(37, Scale(0.005));
    let scn = builtin("delay-spike").unwrap();
    let cycles = scn.cycles_hint.unwrap();
    let mut cfg = ProtocolConfig::paper_default(cycles);
    cfg.eval.n_peers = 10;
    cfg.seed = 37;
    cfg.scenario = Some(scn);
    let res = run(cfg, &ds);
    assert_eq!(res.stats.messages_blocked, 0);
    let first = res.curve.points.first().unwrap().err_mean;
    let last = res.curve.final_error();
    assert!(last < first && last < 0.25, "{first} -> {last}");
}

/// Every built-in runs end to end through BOTH engines from the one shared
/// definition (the ≤128-node deployment leg lives in tests/deployment.rs).
#[test]
fn builtin_library_runs_in_both_engines() {
    let ds = urls_like(38, Scale(0.002)); // 20 nodes (>= 16 for the trace)
    for &name in golf::scenario::builtin_names() {
        let scn = builtin(name).unwrap();
        // validated against this dataset + its own suggested horizon, but
        // run shorter where the timeline allows it (phases must fit)
        let cycles = scn.cycles_hint.unwrap();
        scn.validate(ds.n_train(), cycles).unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut cfg = ProtocolConfig::paper_default(cycles);
        cfg.eval.n_peers = 6;
        cfg.eval.at_cycles = vec![1, cycles / 2, cycles];
        cfg.seed = 38;
        cfg.scenario = Some(scn);
        let ev = run(cfg.clone(), &ds);
        assert_eq!(ev.curve.points.len(), 3, "{name}: event-driven curve");
        let mut be = NativeBackend::new();
        let bt = run_batched(cfg, &ds, &mut be).unwrap();
        assert_eq!(bt.curve.points.len(), 3, "{name}: batched curve");
    }
}

/// Acceptance: scenario grids through `run_grid` are bit-for-bit identical
/// in parallel and serial execution.
#[test]
fn scenario_sweep_parallel_bitwise_equals_serial() {
    let mk = |threads: usize| {
        let mut cfg = sweep::SweepConfig::paper_grid(0.01, 8, 77);
        cfg.variants = vec![Variant::Mu];
        cfg.failures = vec![false];
        cfg.scenarios = vec!["none".into(), "paper-fig3".into(), "drift".into()];
        cfg.cycles = 120; // fits the drift event at cycle 100
        cfg.replicates = 1;
        cfg.eval_peers = 8;
        cfg.threads = threads;
        sweep::run_grid(&cfg).unwrap()
    };
    let serial = mk(1);
    let parallel = mk(4);
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(serial.len(), 3 * 3); // three datasets x three scenarios
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.dataset, b.dataset);
        assert_eq!(a.scenario, b.scenario);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.curve.points.len(), b.curve.points.len());
        for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
            assert_eq!(pa.cycle, pb.cycle);
            assert_eq!(
                pa.err_mean, pb.err_mean,
                "{}/{} parallel != serial",
                a.dataset, a.scenario
            );
        }
        assert_eq!(a.stats.messages_sent, b.stats.messages_sent);
    }
}

/// One timeline definition exercising several axes at once (partition +
/// drop phase + drift + leave), sanity-run through the event engine with a
/// few assertions about which machinery engaged.
#[test]
fn combined_timeline_engages_every_axis() {
    let ds = urls_like(39, Scale(0.004));
    let mut scn = Scenario::empty("combined");
    scn.drop = Some(0.1);
    scn.delay = Some(DelaySpec::Fixed(0.01));
    scn.phases.push(Phase {
        name: "split".into(),
        from: 5,
        to: 12,
        drop: None,
        delay: None,
        partition: Some(PartitionSpec::Mod(2)),
        leave: None,
    });
    scn.phases.push(Phase {
        name: "storm".into(),
        from: 15,
        to: 22,
        drop: Some(0.8),
        delay: Some(DelaySpec::Uniform(1.0, 4.0)),
        partition: None,
        leave: Some(0.25),
    });
    scn.events.push(PointEvent {
        name: "invert".into(),
        at: 28,
        action: PointAction::Drift,
    });
    scn.validate(ds.n_train(), 40).unwrap();
    let mut cfg = ProtocolConfig::paper_default(40);
    cfg.eval.n_peers = 10;
    cfg.seed = 39;
    cfg.scenario = Some(scn);
    let res = run(cfg, &ds);
    assert!(res.stats.messages_blocked > 0, "partition phase");
    assert!(res.stats.messages_dropped > 0, "baseline + storm drop");
    assert!(res.stats.messages_lost_offline > 0, "leave wave");
    assert!(!res.curve.points.is_empty());
}

/// Trace validation end to end through a real (temp) trace file referenced
/// from a .scn document.
#[test]
fn scn_file_with_trace_churn_file() {
    let dir = std::env::temp_dir();
    let trace_path = dir.join("golf_scenario_trace_test.trace");
    std::fs::write(&trace_path, "# node from to\n0 0 5\n1 2 9\n").unwrap();
    let scn_text = format!(
        "[scenario]\nname = traced\nchurn = trace:{}\n",
        trace_path.display()
    );
    let scn = Scenario::from_ini(&scn_text).unwrap();
    match &scn.churn {
        Some(ChurnSpec::Trace(entries)) => {
            assert_eq!(
                entries,
                &vec![
                    TraceEntry { node: 0, from: 0, to: 5 },
                    TraceEntry { node: 1, from: 2, to: 9 },
                ]
            );
        }
        other => panic!("expected trace churn, got {other:?}"),
    }
    // unknown node ids in the trace are caught at validation
    assert!(scn.validate(1, 20).is_err());
    scn.validate(5, 20).unwrap();
    std::fs::remove_file(&trace_path).ok();
}
