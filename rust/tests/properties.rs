//! Property-based tests over the paper's invariants, via the in-repo
//! `util::check::forall` runner.

use golf::data::dataset::Row;
use golf::data::matrix::Matrix;
use golf::data::{libsvm, Csr, Examples};
use golf::engine::native::NativeBackend;
use golf::engine::{Backend, LearnerKind, StepBatch, StepOp};
use golf::gossip::cache::ModelCache;
use golf::gossip::create_model::{create_model, Variant};
use golf::learning::{Adaline, Learner, LinearModel, MergeMode, Pegasos};
use golf::sim::event::{Event, EventQueue};
use golf::util::check::{close_f32, forall};
use golf::util::rng::Rng;

fn rand_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
    (0..d).map(|_| rng.normal() as f32).collect()
}

#[test]
fn prop_merge_is_commutative_and_averaging() {
    forall(
        101,
        200,
        |rng| {
            let d = 1 + rng.below_usize(40);
            (
                rand_vec(rng, d),
                rand_vec(rng, d),
                rng.below(1000),
                rng.below(1000),
            )
        },
        |(wa, wb, ta, tb)| {
            let a = LinearModel::from_weights(wa.clone(), *ta);
            let b = LinearModel::from_weights(wb.clone(), *tb);
            let ab = LinearModel::merge(&a, &b);
            let ba = LinearModel::merge(&b, &a);
            close_f32(&ab.weights(), &ba.weights(), 1e-6, 1e-7)?;
            if ab.t != ta.max(tb).to_owned() {
                return Err(format!("t {} != max({ta},{tb})", ab.t));
            }
            // averaging: each coordinate is the midpoint
            for (i, ((&x, &y), m)) in
                wa.iter().zip(wb.iter()).zip(ab.weights()).enumerate()
            {
                let expect = 0.5 * (x + y);
                if (m - expect).abs() > 1e-6 {
                    return Err(format!("coord {i}: {m} != {expect}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_adaline_update_merge_commute_eq8() {
    // Eq. (8): update(avg(w1,w2)) == avg(update(w1), update(w2))
    forall(
        102,
        200,
        |rng| {
            let d = 1 + rng.below_usize(30);
            (
                rand_vec(rng, d),
                rand_vec(rng, d),
                rand_vec(rng, d),
                rng.sign(),
                0.001 + rng.next_f32() * 0.3,
            )
        },
        |(w1, w2, x, y, eta)| {
            let ad = Adaline::new(*eta);
            let a = LinearModel::from_weights(w1.clone(), 0);
            let b = LinearModel::from_weights(w2.clone(), 0);
            let mut avg_up = LinearModel::merge(&a, &b);
            ad.update(&mut avg_up, &Row::Dense(x), *y);
            let (mut ua, mut ub) = (a, b);
            ad.update(&mut ua, &Row::Dense(x), *y);
            ad.update(&mut ub, &Row::Dense(x), *y);
            let up_avg = LinearModel::merge(&ua, &ub);
            close_f32(&avg_up.weights(), &up_avg.weights(), 1e-4, 1e-5)
        },
    );
}

#[test]
fn prop_weighted_vote_equals_average_model_eq7() {
    forall(
        103,
        200,
        |rng| {
            let d = 1 + rng.below_usize(20);
            let k = 1 + rng.below_usize(10);
            let models: Vec<Vec<f32>> = (0..k).map(|_| rand_vec(rng, d)).collect();
            let x = rand_vec(rng, d);
            (models, x)
        },
        |(models, x)| {
            let d = x.len();
            let mut cache = ModelCache::new(models.len());
            let mut sum = vec![0.0f32; d];
            for w in models {
                for (s, &v) in sum.iter_mut().zip(w) {
                    *s += v;
                }
                cache.add(LinearModel::from_weights(w.clone(), 0));
            }
            let avg: Vec<f32> =
                sum.iter().map(|s| s / models.len() as f32).collect();
            let avg_model = LinearModel::from_weights(avg, 0);
            let xr = Row::Dense(x);
            let vote = golf::gossip::Predictor::WeightedVote.predict(&cache, &xr);
            if vote != avg_model.predict(&xr) {
                return Err(format!("vote {vote} != avg-model prediction"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pegasos_update_bounded_step() {
    // each Pegasos step moves w by at most eta*(lam*|w| + |x|) — sanity
    // bound derived from the update rule; catches sign/step-size bugs
    forall(
        104,
        300,
        |rng| {
            let d = 1 + rng.below_usize(25);
            (
                rand_vec(rng, d),
                rand_vec(rng, d),
                rng.sign(),
                1 + rng.below(1000),
                [1e-4, 1e-3, 1e-2, 0.1][rng.below_usize(4)],
            )
        },
        |(w0, x, y, t0, lam)| {
            let p = Pegasos::new(*lam);
            let mut m = LinearModel::from_weights(w0.clone(), *t0);
            p.update(&mut m, &Row::Dense(x), *y);
            let t1 = (*t0 + 1) as f32;
            let eta = 1.0 / (lam * t1);
            let wnorm = w0.iter().map(|v| v * v).sum::<f32>().sqrt();
            let xnorm = x.iter().map(|v| v * v).sum::<f32>().sqrt();
            let moved: f32 = m
                .weights()
                .iter()
                .zip(w0)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            let bound = eta * lam * wnorm + eta * xnorm + 1e-3;
            if moved > bound {
                return Err(format!("step {moved} exceeds bound {bound}"));
            }
            if m.t != t0 + 1 {
                return Err("t not incremented".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_create_model_rw_independent_of_m2() {
    forall(
        105,
        100,
        |rng| {
            let d = 1 + rng.below_usize(15);
            (rand_vec(rng, d), rand_vec(rng, d), rand_vec(rng, d), rng.sign())
        },
        |(w1, w2, x, y)| {
            let l = Learner::pegasos(0.01);
            let m1 = LinearModel::from_weights(w1.clone(), 3);
            let m2 = LinearModel::from_weights(w2.clone(), 9);
            let zeros = LinearModel::zeros(w1.len());
            let a = create_model(
                Variant::Rw, MergeMode::Average, &l, m1.clone(), &m2, &Row::Dense(x), *y,
            );
            let b = create_model(
                Variant::Rw, MergeMode::Average, &l, m1, &zeros, &Row::Dense(x), *y,
            );
            close_f32(&a.weights(), &b.weights(), 1e-6, 1e-7)
        },
    );
}

#[test]
fn prop_batched_native_matches_scalar_path() {
    // batching must be a pure reorganization: batched MU == scalar MU
    forall(
        106,
        60,
        |rng| {
            let d = 1 + rng.below_usize(12);
            let b = 1 + rng.below_usize(20);
            let mut sb = StepBatch::default();
            sb.resize(b, d);
            for v in sb.w1.iter_mut().chain(&mut sb.w2).chain(&mut sb.x) {
                *v = rng.normal() as f32;
            }
            for i in 0..b {
                sb.y[i] = rng.sign();
                sb.t1[i] = rng.below(100) as f32;
                sb.t2[i] = rng.below(100) as f32;
            }
            sb
        },
        |sb| {
            let mut sb = sb.clone();
            let (b, d) = (sb.b, sb.d);
            let op = StepOp {
                learner: LearnerKind::Pegasos,
                variant: Variant::Mu,
                hp: 0.05,
                merge: MergeMode::Average,
            };
            let learner = Learner::pegasos(0.05);
            let mut expect = Vec::new();
            for i in 0..b {
                let m1 = LinearModel::from_weights(
                    sb.w1[i * d..(i + 1) * d].to_vec(),
                    sb.t1[i] as u64,
                );
                let m2 = LinearModel::from_weights(
                    sb.w2[i * d..(i + 1) * d].to_vec(),
                    sb.t2[i] as u64,
                );
                let c = create_model(
                    Variant::Mu,
                    MergeMode::Average,
                    &learner,
                    m1,
                    &m2,
                    &Row::Dense(&sb.x[i * d..(i + 1) * d]),
                    sb.y[i],
                );
                expect.push(c);
            }
            NativeBackend::new().step(&op, &mut sb).map_err(|e| e.to_string())?;
            for i in 0..b {
                close_f32(
                    &sb.out_w[i * d..(i + 1) * d],
                    &expect[i].weights(),
                    1e-4,
                    1e-5,
                )?;
                if sb.out_t[i] as u64 != expect[i].t {
                    return Err(format!("t mismatch row {i}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_event_queue_total_order() {
    forall(
        107,
        50,
        |rng| {
            let n = 1 + rng.below_usize(200);
            (0..n).map(|_| rng.below(1000)).collect::<Vec<u64>>()
        },
        |times| {
            let mut q = EventQueue::new();
            for &t in times {
                q.push(t, Event::Eval);
            }
            let mut prev = 0u64;
            while let Some((t, _)) = q.pop() {
                if t < prev {
                    return Err(format!("out of order: {t} after {prev}"));
                }
                prev = t;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_event_queue_fifo_tie_breaking() {
    // Events pushed at equal timestamps must pop in insertion order — the
    // queue's total order is a *stable* sort by time.  This is what makes
    // whole runs (and the micro-batch flush order) deterministic per seed.
    forall(
        112,
        80,
        |rng| {
            let n = 1 + rng.below_usize(150);
            // few distinct timestamps -> many ties
            (0..n).map(|_| rng.below(8)).collect::<Vec<u64>>()
        },
        |times| {
            let mut q = EventQueue::new();
            for (id, &t) in times.iter().enumerate() {
                q.push(t, Event::Join { node: id });
            }
            let mut expect: Vec<(u64, usize)> =
                times.iter().copied().zip(0..times.len()).collect();
            expect.sort_by_key(|&(t, _)| t); // stable: preserves insertion order on ties
            let mut got = Vec::new();
            while let Some((t, ev)) = q.pop() {
                let Event::Join { node } = ev else {
                    return Err("unexpected event type".into());
                };
                got.push((t, node));
            }
            if got != expect {
                return Err(format!("pop order {got:?} != stable order {expect:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_keyed_queue_pop_order_independent_of_arrival_order() {
    // Cross-shard delivery correctness (DESIGN.md §13) rests on this: every
    // event carries a globally unique (time, class, src, seq) key, so the
    // pop order of a KeyedQueue is a pure function of the key *set* — the
    // order in which delivery lanes happened to hand envelopes over (any
    // permutation) cannot change what the shard processes next.
    use golf::sim::event::{EventKey, KeyedQueue};
    forall(
        114,
        80,
        |rng| {
            let n = 1 + rng.below_usize(120);
            let keys: Vec<EventKey> = (0..n)
                .map(|i| {
                    // few distinct times and sources -> dense key collisions
                    // everywhere except the uniqueness-carrying seq
                    if rng.chance(0.5) {
                        EventKey::deliver(rng.below(6), rng.below_usize(4), i as u64)
                    } else {
                        EventKey::tick(rng.below(6), i)
                    }
                })
                .collect();
            // a second, independently shuffled arrival order of the same set
            let perm = rng.sample_indices(keys.len(), keys.len());
            (keys, perm)
        },
        |(keys, perm)| {
            let mut q1 = KeyedQueue::new();
            for (i, k) in keys.iter().enumerate() {
                q1.push(*k, i);
            }
            let mut q2 = KeyedQueue::new();
            for &i in perm {
                q2.push(keys[i], i);
            }
            let mut prev: Option<EventKey> = None;
            loop {
                match (q1.pop(), q2.pop()) {
                    (None, None) => return Ok(()),
                    (Some((ka, ea)), Some((kb, eb))) => {
                        if ka != kb || ea != eb {
                            return Err(format!(
                                "pop diverged: {ka:?}/{ea} vs {kb:?}/{eb}"
                            ));
                        }
                        if let Some(p) = prev {
                            if !(p < ka) {
                                return Err(format!("non-increasing keys {p:?} -> {ka:?}"));
                            }
                        }
                        prev = Some(ka);
                    }
                    _ => return Err("queues drained at different lengths".into()),
                }
            }
        },
    );
}

#[test]
fn prop_scale_floor_rematerialization_preserves_predictions() {
    // Repeated lazy down-scaling drives the internal scale through the
    // SCALE_FLOOR re-materialization (linear.rs).  The effective weights —
    // and therefore margins and predictions — must track an eagerly-computed
    // f64 reference through the floor crossing, and stay finite.
    forall(
        113,
        100,
        |rng| {
            let d = 1 + rng.below_usize(16);
            let w: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            // 30 factors from {0.05, 0.1, 0.2}: the product is at most
            // 0.2^30 ~ 1e-21 < SCALE_FLOOR = 1e-20, so every case crosses
            // the floor, and at least 0.05^30 ~ 1e-39, so the materialized
            // weights stay representable
            let factors: Vec<f32> =
                (0..30).map(|_| [0.05f32, 0.1, 0.2][rng.below_usize(3)]).collect();
            (w, x, factors)
        },
        |(w, x, factors)| {
            let mut m = LinearModel::from_weights(w.clone(), 0);
            let mut eager = 1.0f64;
            for &f in factors {
                m.scale_by(f);
                eager *= f as f64;
            }
            if eager >= 1e-20 {
                return Err(format!("case does not cross the floor: scale {eager}"));
            }
            for (i, (&wi, got)) in w.iter().zip(m.weights()).enumerate() {
                let expect = (wi as f64 * eager) as f32;
                if !got.is_finite() {
                    return Err(format!("coord {i} not finite: {got}"));
                }
                let tol = 1e-3 * expect.abs().max(got.abs()) + 1e-32;
                if (got - expect).abs() > tol {
                    return Err(format!("coord {i}: {got} vs eager {expect}"));
                }
            }
            // prediction must agree with the eager reference whenever the
            // raw margin is safely away from the f32 noise floor (a positive
            // scale can never flip the margin sign)
            let dot_ref: f64 = w.iter().zip(x).map(|(&a, &b)| a as f64 * b as f64).sum();
            if dot_ref.abs() > 1e-3 {
                let pred_ref = if dot_ref * eager > 0.0 { 1.0 } else { -1.0 };
                let pred = m.predict(&Row::Dense(x));
                if pred != pred_ref {
                    return Err(format!("prediction {pred} != reference {pred_ref}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_never_exceeds_capacity_and_keeps_freshest() {
    forall(
        108,
        100,
        |rng| {
            let cap = 1 + rng.below_usize(12);
            let n = 1 + rng.below_usize(50);
            (cap, (0..n).map(|i| i as u64).collect::<Vec<u64>>())
        },
        |(cap, seq)| {
            let mut c = ModelCache::new(*cap);
            for &t in seq {
                c.add(LinearModel::from_weights(vec![t as f32], t));
                if c.len() > *cap {
                    return Err("capacity exceeded".into());
                }
                if c.freshest().t != t {
                    return Err("freshest is not last added".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_libsvm_roundtrip() {
    forall(
        109,
        60,
        |rng| {
            let d = 1 + rng.below_usize(30);
            let n = 1 + rng.below_usize(20);
            let mut m = Csr::new(d);
            let mut ys = Vec::new();
            for _ in 0..n {
                let mut entries = Vec::new();
                for j in 0..d {
                    if rng.chance(0.3) {
                        // quantized values survive the float round-trip
                        let v = (rng.normal() * 8.0).round() as f32 / 4.0;
                        if v != 0.0 {
                            entries.push((j as u32, v));
                        }
                    }
                }
                m.push_row(&entries);
                ys.push(rng.sign());
            }
            (m, ys)
        },
        |(m, ys)| {
            // serialize to libsvm text, reparse, compare
            let mut text = String::new();
            for i in 0..m.rows {
                let (idx, val) = m.row(i);
                text.push_str(if ys[i] > 0.0 { "+1" } else { "-1" });
                for (&j, &v) in idx.iter().zip(val) {
                    text.push_str(&format!(" {}:{}", j + 1, v));
                }
                text.push('\n');
            }
            let (x2, y2) = libsvm::parse(text.as_bytes(), Some(m.cols))
                .map_err(|e| e.to_string())?;
            if y2 != *ys {
                return Err("labels differ".into());
            }
            for i in 0..m.rows {
                let (i1, v1) = m.row(i);
                match x2.row(i) {
                    Row::Sparse(i2, v2) => {
                        if i1 != i2 || v1 != v2 {
                            return Err(format!("row {i} differs"));
                        }
                    }
                    _ => return Err("expected sparse".into()),
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bitset_matches_vec_bool_reference() {
    // The packed liveness bitset (DESIGN.md §14) replaces the per-shard
    // `Vec<bool>` replicas, so every observer (test/count_ones/iter_ones)
    // must agree with a `Vec<bool>` reference model after any sequence of
    // mutations — including `grow`, which must expose false bits only.
    use golf::util::bitset::Bitset;
    forall(
        115,
        120,
        |rng| {
            let len = 1 + rng.below_usize(200);
            let init: Vec<bool> = (0..len).map(|_| rng.chance(0.5)).collect();
            // ops: 0 set, 1 clear, 2 assign, 3 fill, 4 grow
            let ops: Vec<(u8, usize, bool)> = (0..rng.below_usize(50))
                .map(|_| (rng.below(5) as u8, rng.below_usize(4096), rng.chance(0.5)))
                .collect();
            (init, ops)
        },
        |(init, ops)| {
            let mut bs = Bitset::from_fn(init.len(), |i| init[i]);
            let mut v = init.clone();
            for &(op, raw, val) in ops {
                let i = raw % v.len(); // scale into the current length
                match op {
                    0 => {
                        bs.set(i);
                        v[i] = true;
                    }
                    1 => {
                        bs.clear(i);
                        v[i] = false;
                    }
                    2 => {
                        bs.assign(i, val);
                        v[i] = val;
                    }
                    3 => {
                        bs.fill(val);
                        v.iter_mut().for_each(|b| *b = val);
                    }
                    _ => {
                        let extra = raw % 9;
                        bs.grow(extra);
                        v.resize(v.len() + extra, false);
                    }
                }
                if bs.len() != v.len() {
                    return Err(format!("len {} != {}", bs.len(), v.len()));
                }
                for (j, &b) in v.iter().enumerate() {
                    if bs.test(j) != b {
                        return Err(format!("bit {j}: {} != {b}", bs.test(j)));
                    }
                }
                let ones: Vec<usize> =
                    v.iter().enumerate().filter(|&(_, &b)| b).map(|(j, _)| j).collect();
                if bs.count_ones() != ones.len() {
                    return Err(format!(
                        "count_ones {} != {}",
                        bs.count_ones(),
                        ones.len()
                    ));
                }
                let got: Vec<usize> = bs.iter_ones().collect();
                if got != ones {
                    return Err(format!("iter_ones {got:?} != {ones:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_feature_projection_preserves_dots() {
    // <project(x), project(w*)> == <x restricted to kept coords, w*>
    forall(
        110,
        80,
        |rng| {
            let d = 4 + rng.below_usize(20);
            let k = 1 + rng.below_usize(d.min(8));
            let keep = rng.sample_indices(d, k);
            let n = 1 + rng.below_usize(10);
            let data: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            (d, keep, n, data)
        },
        |(d, keep, n, data)| {
            let m = Matrix::from_vec(*n, *d, data.clone());
            let p = golf::data::features::project(&Examples::Dense(m.clone()), keep);
            for i in 0..*n {
                for (new_j, &old_j) in keep.iter().enumerate() {
                    if p.row(i)[new_j] != m.row(i)[old_j] {
                        return Err(format!("({i},{new_j}) mismatch"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Every topology generator must emit a simple, symmetric graph whose CSR
/// adjacency, canonical edge list, and structural metrics agree — and the
/// family-specific guarantees (exact circulant/regular degree, torus and BA
/// connectivity, seed determinism) must hold for arbitrary feasible sizes.
#[test]
fn prop_topology_generators_well_formed() {
    use golf::p2p::{Topology, TopologySpec};
    forall(
        116,
        60,
        |rng| {
            // one feasible (family, n) per case; kreg uses the
            // allow-disconnected prefix because a random k-regular graph
            // may legitimately split into components
            let (spec, n) = match rng.below(4) {
                0 => {
                    let k = 1 + rng.below_usize(3);
                    (format!("ring:{k}"), 2 * k + 1 + rng.below_usize(60))
                }
                1 => ("grid".to_string(), 2 + rng.below_usize(80)),
                2 => {
                    let k = 3 + rng.below_usize(2);
                    let mut n = k + 1 + rng.below_usize(40);
                    if n * k % 2 != 0 {
                        n += 1;
                    }
                    (format!("allow-disconnected:kreg:{k}"), n)
                }
                _ => {
                    let m = 1 + rng.below_usize(3);
                    (format!("ba:{m}"), m + 2 + rng.below_usize(60))
                }
            };
            (spec, n, rng.below(1000))
        },
        |(spec_str, n, seed)| {
            let spec = TopologySpec::parse(spec_str)?.ok_or("spec parsed to complete")?;
            let t = Topology::build(&spec, *n, *seed)?;
            let m = t.metrics();
            let mut deg_sum = 0usize;
            let (mut dmin, mut dmax) = (usize::MAX, 0usize);
            for v in 0..*n {
                let nbrs = t.neighbors(v);
                deg_sum += nbrs.len();
                dmin = dmin.min(nbrs.len());
                dmax = dmax.max(nbrs.len());
                for (i, &w) in nbrs.iter().enumerate() {
                    if w as usize == v {
                        return Err(format!("{spec_str}: self loop at {v}"));
                    }
                    if w as usize >= *n {
                        return Err(format!("{spec_str}: neighbor {w} >= n = {n}"));
                    }
                    if i > 0 && nbrs[i - 1] >= w {
                        return Err(format!("{spec_str}: row {v} not sorted/deduped"));
                    }
                    if !t.has_edge(w as usize, v) {
                        return Err(format!("{spec_str}: edge {v}-{w} not symmetric"));
                    }
                }
            }
            if deg_sum != 2 * t.edges().len() {
                return Err(format!(
                    "{spec_str}: degree sum {deg_sum} != 2 x {} edges",
                    t.edges().len()
                ));
            }
            if (m.nodes, m.edges, m.degree_min, m.degree_max)
                != (*n, t.edges().len(), dmin, dmax)
            {
                return Err(format!("{spec_str}: metrics disagree with the graph"));
            }
            match &spec.kind {
                golf::p2p::TopologyKind::Ring { k } => {
                    if dmin != 2 * k || dmax != 2 * k {
                        return Err(format!("ring:{k} degree {dmin}..{dmax} != {}", 2 * k));
                    }
                    if m.components != 1 {
                        return Err("ring is disconnected".into());
                    }
                }
                golf::p2p::TopologyKind::Grid => {
                    if m.components != 1 {
                        return Err("torus is disconnected".into());
                    }
                }
                golf::p2p::TopologyKind::KRegular { k } => {
                    if dmin != *k || dmax != *k {
                        return Err(format!("kreg:{k} degree {dmin}..{dmax} != {k}"));
                    }
                }
                golf::p2p::TopologyKind::BarabasiAlbert { m: ba_m } => {
                    if m.components != 1 {
                        return Err("BA graph is disconnected".into());
                    }
                    if dmin < *ba_m {
                        return Err(format!("ba:{ba_m} has degree-{dmin} node"));
                    }
                }
                _ => {}
            }
            // seed determinism: the same (spec, n, seed) rebuilds the
            // identical edge set
            let t2 = Topology::build(&spec, *n, *seed)?;
            if t.edges() != t2.edges() {
                return Err(format!("{spec_str}: rebuild with same seed differs"));
            }
            Ok(())
        },
    );
}

/// `graph-inline:` edge lists canonicalize (sorted, deduped, min-max
/// oriented) and round-trip exactly through `parse` ↔ `name`, however the
/// input pairs are ordered, reversed, or duplicated.
#[test]
fn prop_topology_edge_list_roundtrip() {
    use golf::p2p::{TopologyKind, TopologySpec};
    forall(
        117,
        80,
        |rng| {
            let n = 2 + rng.below_usize(30);
            let mut canon: Vec<(usize, usize)> = Vec::new();
            for a in 0..n {
                for b in (a + 1)..n {
                    if rng.chance(0.15) {
                        canon.push((a, b));
                    }
                }
            }
            if canon.is_empty() {
                canon.push((0, 1));
            }
            // a messy rendering of the same set: shuffled order, random
            // orientation, some pairs repeated
            let mut messy: Vec<(usize, usize)> = canon.clone();
            for &e in &canon {
                if rng.chance(0.3) {
                    messy.push(e);
                }
            }
            let order = rng.sample_indices(messy.len(), messy.len());
            let rendered: Vec<String> = order
                .iter()
                .map(|&i| {
                    let (a, b) = messy[i];
                    if rng.chance(0.5) {
                        format!("{a}-{b}")
                    } else {
                        format!("{b}-{a}")
                    }
                })
                .collect();
            (canon, format!("graph-inline:{}", rendered.join(",")))
        },
        |(canon, messy_spec)| {
            let spec = TopologySpec::parse(messy_spec)?.ok_or("parsed to complete")?;
            let TopologyKind::GraphInline { edges } = &spec.kind else {
                return Err("did not parse as an inline graph".into());
            };
            if edges != canon {
                return Err(format!("canonicalized {edges:?} != expected {canon:?}"));
            }
            let name = spec.name();
            let reparsed = TopologySpec::parse(&name)?.ok_or("name parsed to complete")?;
            if reparsed != spec {
                return Err(format!("{name:?} did not round-trip"));
            }
            Ok(())
        },
    );
}

/// The node-group readiness loop depends on partial reads being lossless:
/// however a routed multi-frame stream is sliced at the socket — 1-byte
/// dribbles, reads straddling frame boundaries, a trailing partial frame —
/// `wire::FrameBuf` must yield exactly the frames a one-shot decode of the
/// same bytes yields, frame for frame.
#[test]
fn prop_frame_buf_incremental_equals_one_shot() {
    use golf::gossip::message::ModelMsg;
    use golf::learning::pairwise;
    use golf::net::wire::{self, FrameBuf};
    use golf::p2p::newscast::Descriptor;

    forall(
        7001,
        120,
        |rng| {
            let n = 1 + rng.below_usize(6);
            let mut msgs = Vec::new();
            for _ in 0..n {
                let d = 1 + rng.below_usize(24);
                let view = (0..rng.below_usize(4))
                    .map(|_| Descriptor { node: rng.below_usize(50), ts: rng.below(1000) })
                    .collect();
                // about half the frames ride an example reservoir at a
                // random fill level (wire v2 tail, DESIGN.md §17)
                let res = if rng.chance(0.5) {
                    let k = 1 + rng.below_usize(8);
                    let mut r = pairwise::reservoir_new(k);
                    for i in 0..rng.below_usize(2 * k + 2) {
                        pairwise::offer(&mut r, i as u32, rng.sign(), rng.next_u64());
                    }
                    r
                } else {
                    Vec::new()
                };
                msgs.push((
                    rng.below_usize(64),
                    ModelMsg {
                        src: rng.below_usize(64),
                        w: rand_vec(rng, d),
                        scale: 1.0,
                        t: rng.below(1000),
                        view,
                        res,
                    },
                ));
            }
            // adversarial read plan: a mix of 1-byte dribbles and short
            // random widths, so chunk edges land inside length headers,
            // inside bodies, and exactly on frame boundaries
            let widths: Vec<usize> = (0..48)
                .map(|_| if rng.below_usize(3) == 0 { 1 } else { 1 + rng.below_usize(13) })
                .collect();
            let trailing = rng.below_usize(12);
            (msgs, widths, trailing)
        },
        |(msgs, widths, trailing)| {
            let mut stream = Vec::new();
            for (dst, m) in msgs {
                stream.extend_from_slice(&wire::encode_routed(*dst, m));
            }
            // a truncated next frame at the tail must neither yield a frame
            // nor poison the ones before it
            let extra = wire::encode_routed(0, &msgs[0].1);
            let cut = (*trailing).min(extra.len() - 1);
            stream.extend_from_slice(&extra[..cut]);

            // reference: the whole stream in one extend
            let mut oneshot = FrameBuf::default();
            oneshot.extend(&stream);
            let mut want = Vec::new();
            while let Some(r) = oneshot.next_routed() {
                want.push(r.map_err(|e| format!("one-shot decode: {e}"))?);
            }
            if want.len() != msgs.len() {
                return Err(format!("one-shot got {} frames, sent {}", want.len(), msgs.len()));
            }

            // incremental: the same bytes through the adversarial read plan
            let mut fb = FrameBuf::default();
            let mut got = Vec::new();
            let (mut pos, mut wi) = (0, 0);
            while pos < stream.len() {
                let end = (pos + widths[wi % widths.len()]).min(stream.len());
                wi += 1;
                fb.extend(&stream[pos..end]);
                pos = end;
                while let Some(r) = fb.next_routed() {
                    got.push(r.map_err(|e| format!("incremental decode: {e}"))?);
                }
            }

            if got.len() != want.len() {
                return Err(format!("incremental got {} frames, want {}", got.len(), want.len()));
            }
            for (i, ((gd, gm), (wd, wm))) in got.iter().zip(&want).enumerate() {
                if gd != wd || gm.src != wm.src || gm.t != wm.t || gm.view != wm.view {
                    return Err(format!("frame {i}: header/view mismatch"));
                }
                if gm.w != wm.w {
                    return Err(format!("frame {i}: weights differ"));
                }
                if gm.res != wm.res {
                    return Err(format!("frame {i}: reservoirs differ"));
                }
            }
            Ok(())
        },
    );
}

/// The example reservoir (DESIGN.md §17) is Vitter's Algorithm R driven by
/// one explicit draw per offer: identical draw streams must rebuild the
/// identical reservoir (this is what makes sharded runs shard-count
/// independent), `seen` must count every offer, occupancy must saturate at
/// the capacity, and every surviving entry must name an offered example with
/// its own label.
#[test]
fn prop_reservoir_offer_deterministic_and_bounded() {
    use golf::learning::pairwise::{self, offer};
    forall(
        118,
        120,
        |rng| {
            let k = 1 + rng.below_usize(16);
            let n = 1 + rng.below_usize(200);
            let draws: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            (k, draws)
        },
        |(k, draws)| {
            let label = |i: usize| if i % 2 == 0 { 1.0f32 } else { -1.0 };
            let mut res = pairwise::reservoir_new(*k);
            let mut res2 = pairwise::reservoir_new(*k);
            for (i, &d) in draws.iter().enumerate() {
                offer(&mut res, i as u32, label(i), d);
                offer(&mut res2, i as u32, label(i), d);
                if pairwise::seen(&res) as usize != i + 1 {
                    return Err(format!("seen {} after {} offers", pairwise::seen(&res), i + 1));
                }
                if pairwise::occupancy(&res) != (i + 1).min(*k) {
                    return Err(format!(
                        "occupancy {} != min({}, {k})",
                        pairwise::occupancy(&res),
                        i + 1
                    ));
                }
            }
            // determinism: same capacity + same draw stream => same reservoir
            if pairwise::seen(&res) != pairwise::seen(&res2) {
                return Err("replay diverged on seen".into());
            }
            let (ea, eb): (Vec<_>, Vec<_>) =
                (pairwise::entries(&res).collect(), pairwise::entries(&res2).collect());
            if ea != eb {
                return Err(format!("replay diverged: {ea:?} != {eb:?}"));
            }
            // every entry is an offered (node, label) pair, each at most once
            let mut seen_nodes = std::collections::HashSet::new();
            for (node, y) in ea {
                if node as usize >= draws.len() {
                    return Err(format!("entry names unoffered node {node}"));
                }
                if y != label(node as usize) {
                    return Err(format!("node {node} carries label {y}"));
                }
                if !seen_nodes.insert(node) {
                    return Err(format!("node {node} appears twice"));
                }
            }
            Ok(())
        },
    );
}

/// Algorithm R's defining property: after `n` offers into a capacity-`k`
/// reservoir, *every* example survives with probability exactly k/n — early
/// arrivals get no advantage.  Checked in aggregate over independent draw
/// streams against a 5-sigma binomial band per example.
#[test]
fn prop_reservoir_inclusion_is_uniform() {
    use golf::learning::pairwise::{self, offer};
    let (k, n, trials) = (8usize, 40usize, 4000usize);
    let mut counts = vec![0usize; n];
    let mut rng = Rng::new(0xA0C);
    for _ in 0..trials {
        let mut res = pairwise::reservoir_new(k);
        for i in 0..n {
            offer(&mut res, i as u32, 1.0, rng.next_u64());
        }
        for (node, _) in pairwise::entries(&res) {
            counts[node as usize] += 1;
        }
    }
    let p = k as f64 / n as f64;
    let expect = trials as f64 * p;
    let tol = 5.0 * (trials as f64 * p * (1.0 - p)).sqrt();
    for (i, &c) in counts.iter().enumerate() {
        assert!(
            (c as f64 - expect).abs() < tol,
            "example {i} survived {c} times, expected {expect:.0} +/- {tol:.0}"
        );
    }
}
