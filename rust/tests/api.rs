//! Facade acceptance tests (DESIGN.md §12): the `RunSpec → Session →
//! Outcome` pipeline, the INI bidirectionality, the typed-rejection matrix,
//! and the live `Observer` stream from the Sim and Batched targets (the
//! Deploy target's stream is pinned in tests/deployment.rs, where socket
//! tests are serialized).

use golf::api::{CurveRecorder, GolfError, NullObserver, RunSpec, SweepAxes, Target};
use golf::config::{BackendChoice, DeploySpec, ExperimentSpec};
use golf::data::synthetic::{spambase_like, urls_like, Scale};
use golf::gossip::create_model::Variant;
use golf::p2p::overlay::SamplerConfig;

// ---------------------------------------------------------------------------
// INI bidirectionality

/// Every `[experiment]` key, set to a non-default value, survives
/// INI → RunSpec → INI → RunSpec.
#[test]
fn ini_roundtrip_every_experiment_key() {
    let text = "
[experiment]
dataset = spambase
scale = 0.02
cycles = 9
variant = um
learner = adaline
lambda = 0.5
eta = 0.01
merge = quorum
reservoir = 4
cache = 5
sampler = newscast
view = 30
failures = extreme
seed = 7
eval_peers = 11
voting = true
similarity = true
backend = event
mode = scalar
coalesce = 3
exec = dense
scenario = paper-fig3
topology = ring:2
";
    let spec = RunSpec::from_ini(text).unwrap();
    // the keys landed
    let e = &spec.experiment;
    assert_eq!(e.dataset, "spambase");
    assert_eq!(e.scale, 0.02);
    assert_eq!(e.cycles, 9);
    assert_eq!(e.variant, Variant::Um);
    assert_eq!(e.learner_name, "adaline");
    assert_eq!(e.lambda, 0.5);
    assert_eq!(e.eta, 0.01);
    assert_eq!(e.merge, golf::learning::MergeMode::Quorum);
    assert_eq!(e.reservoir, 4);
    assert_eq!(e.cache, 5);
    assert_eq!(e.sampler, SamplerConfig::Newscast { view_size: 30 });
    assert!(e.failures);
    assert_eq!(e.seed, 7);
    assert_eq!(e.eval_peers, 11);
    assert!(e.voting && e.similarity);
    assert_eq!(e.backend, BackendChoice::Event);
    assert_eq!(e.mode, "scalar");
    assert_eq!(e.coalesce, 3);
    assert_eq!(e.scenario.as_ref().unwrap().name, "paper-fig3");
    assert_eq!(e.topology.as_ref().unwrap().name(), "ring:2");
    assert_eq!(spec.target, Target::Sim);
    // ... and round-trip exactly
    let round = RunSpec::from_ini(&spec.to_ini()).unwrap();
    assert_eq!(round, spec, "\n{}", spec.to_ini());
    // non-newscast samplers round-trip without a view key
    let mut oracle = spec.clone();
    oracle.experiment.sampler = SamplerConfig::Oracle;
    let round = RunSpec::from_ini(&oracle.to_ini()).unwrap();
    assert_eq!(round, oracle);
}

/// `sampler` + `view` land deterministically regardless of the map's
/// iteration order (regression: `sampler = newscast` used to be able to
/// reset an already-applied `view`).
#[test]
fn sampler_and_view_apply_in_fixed_order() {
    for _ in 0..32 {
        let mut kv = std::collections::HashMap::new();
        kv.insert("view".to_string(), "30".to_string());
        kv.insert("sampler".to_string(), "newscast".to_string());
        let mut spec = ExperimentSpec::default();
        spec.apply(&kv).unwrap();
        assert_eq!(spec.sampler, SamplerConfig::Newscast { view_size: 30 });
    }
    // view without a newscast sampler is a typed config error now
    let mut kv = std::collections::HashMap::new();
    kv.insert("sampler".to_string(), "oracle".to_string());
    kv.insert("view".to_string(), "30".to_string());
    let e = ExperimentSpec::default().apply(&kv).unwrap_err();
    assert!(matches!(e, GolfError::Config(_)), "{e}");
}

/// Every `[deploy]` key round-trips, and a `[deploy]` section selects
/// `Target::Deploy`.
#[test]
fn ini_roundtrip_deploy_keys() {
    let text = "
[experiment]
dataset = urls
scale = 0.01
cycles = 12

[deploy]
delta_ms = 25
nodes = 40
node_groups = 3
";
    let spec = RunSpec::from_ini(text).unwrap();
    assert_eq!(spec.target, Target::Deploy);
    assert_eq!(spec.delta_ms, 25);
    assert_eq!(spec.nodes, 40);
    assert_eq!(spec.node_groups, 3);
    let round = RunSpec::from_ini(&spec.to_ini()).unwrap();
    assert_eq!(round, spec, "\n{}", spec.to_ini());
}

/// Every `[sweep]` key round-trips.
#[test]
fn ini_roundtrip_sweep_axes() {
    let text = "
[experiment]
scale = 0.01
cycles = 4
seed = 5

[sweep]
variants = rw,mu,um
failures = none,extreme
scenarios = none,paper-fig3
topologies = complete,ring:2
replicates = 2
threads = 3
";
    let spec = RunSpec::from_ini(text).unwrap();
    let axes = spec.sweep.as_ref().unwrap();
    assert_eq!(axes.variants, vec![Variant::Rw, Variant::Mu, Variant::Um]);
    assert_eq!(axes.failures, vec![false, true]);
    assert_eq!(axes.scenarios, vec!["none", "paper-fig3"]);
    assert_eq!(axes.topologies, vec!["complete", "ring:2"]);
    assert_eq!(axes.replicates, 2);
    assert_eq!(axes.threads, 3);
    let round = RunSpec::from_ini(&spec.to_ini()).unwrap();
    assert_eq!(round, spec, "\n{}", spec.to_ini());
}

/// A custom (non-built-in) scenario embeds as full sections and survives
/// the round trip.
#[test]
fn ini_roundtrip_embedded_scenario() {
    let text = "
[experiment]
dataset = urls
scale = 0.01
cycles = 60

[scenario]
name = blip
drop = 0.1

[phase.outage]
from = 10
to = 30
drop = 0.9

[event.invert]
at = 40
action = drift
";
    let spec = RunSpec::from_ini(text).unwrap();
    let scn = spec.experiment.scenario.as_ref().unwrap();
    assert_eq!(scn.name, "blip");
    assert_eq!(scn.phases.len(), 1);
    assert_eq!(scn.events.len(), 1);
    let ini = spec.to_ini();
    assert!(ini.contains("[phase.outage]"), "\n{ini}");
    let round = RunSpec::from_ini(&ini).unwrap();
    assert_eq!(round, spec, "\n{ini}");
}

/// from_spec/to_spec and from_deploy_spec/to_deploy_spec are inverses.
#[test]
fn spec_conversions_are_inverses() {
    let exp = ExperimentSpec {
        backend: BackendChoice::BatchedNative,
        cycles: 17,
        ..Default::default()
    };
    let spec = RunSpec::from_spec(exp.clone());
    assert_eq!(spec.target, Target::Batched);
    assert_eq!(spec.to_spec(), exp);

    let dspec = DeploySpec { experiment: exp, delta_ms: 77, nodes: 9, node_groups: 2 };
    let spec = RunSpec::from_deploy_spec(dspec.clone());
    assert_eq!(spec.target, Target::Deploy);
    assert_eq!(spec.to_deploy_spec(), dspec);
}

/// Unknown sections and top-level keys are typed config errors — one
/// schema, nothing silently ignored.
#[test]
fn ini_rejects_unknown_sections_and_stray_keys() {
    let e = RunSpec::from_ini("[expermient]\ndataset = urls\n").unwrap_err();
    assert!(matches!(e, GolfError::Config(_)), "{e}");
    let e = RunSpec::from_ini("dataset = urls\n").unwrap_err();
    assert!(matches!(e, GolfError::Config(_)), "{e}");
}

// ---------------------------------------------------------------------------
// validation matrix

fn kind(e: &GolfError) -> &'static str {
    e.kind()
}

#[test]
fn rejects_invalid_combinations_with_typed_errors() {
    // Target::Deploy + sampler = matching (simulator-only baseline)
    let e = RunSpec::new("urls")
        .scale(0.005)
        .sampler(SamplerConfig::Matching)
        .deploy(10, 0)
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");

    // sweep axes on a deployment
    let e = RunSpec::new("urls")
        .deploy(10, 0)
        .sweep(SweepAxes::default())
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");

    // sweep axes on a batched backend
    let e = RunSpec::new("urls")
        .backend(BackendChoice::BatchedNative)
        .sweep(SweepAxes::default())
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");

    // sweep with an unknown scenario name
    let axes = SweepAxes { scenarios: vec!["warp".into()], ..Default::default() };
    let e = RunSpec::new("urls").sweep(axes).build().unwrap_err();
    assert_eq!(kind(&e), "scenario", "{e}");

    // sweep with an attached scenario timeline (the grid takes its scenario
    // axis from the [sweep] section; a timeline would be silently dropped)
    let e = RunSpec::new("urls")
        .builtin_scenario("paper-fig3")
        .unwrap()
        .sweep(SweepAxes::default())
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");

    // a deployment has no compute backend (DeployConfig runs natively);
    // a batched/PJRT backend must not be silently ignored
    let e = RunSpec::new("urls")
        .scale(0.005)
        .backend(BackendChoice::BatchedNative)
        .deploy(10, 0)
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");

    // unknown dataset
    let e = RunSpec::new("nope").build().unwrap_err();
    assert_eq!(kind(&e), "data", "{e}");

    // bad stepping mode
    let mut spec = RunSpec::new("urls").scale(0.005);
    spec.experiment.mode = "warp".into(); // the builder only offers valid modes
    let e = spec.build().unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");

    // voting needs the event-driven simulator
    let e = RunSpec::new("urls")
        .scale(0.005)
        .backend(BackendChoice::BatchedNative)
        .voting(true)
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");

    // more deployment nodes than training rows
    let e = RunSpec::new("urls")
        .scale(0.005)
        .deploy(10, 2000)
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "data", "{e}");

    // a scenario whose timeline cannot fit the horizon
    let e = RunSpec::new("urls")
        .scale(0.005)
        .cycles(6)
        .builtin_scenario("partition-heal")
        .unwrap()
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "scenario", "{e}");

    // build_with against a differently named dataset
    let ds = spambase_like(1, Scale(0.01));
    let e = RunSpec::new("urls").build_with(&ds).unwrap_err();
    assert_eq!(kind(&e), "data", "{e}");
}

/// Pairwise/quorum validation matrix (DESIGN.md §17): every invalid
/// combination is a typed config error with its distinct exit code, raised
/// at build time — never a panic inside a running simulation.
#[test]
fn rejects_invalid_pairwise_combinations_with_typed_errors() {
    use golf::learning::MergeMode;

    // the quorum vote is coordinate agreement between gossip partners; the
    // PERFECT MATCHING baseline has no overlay to agree over
    let e = RunSpec::new("urls")
        .scale(0.005)
        .sampler(SamplerConfig::Matching)
        .merge(MergeMode::Quorum)
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");
    assert_eq!(e.exit_code(), 2);

    // a pairwise learner with no reservoir slot can never form a pair
    let e = RunSpec::new("urls")
        .scale(0.005)
        .learner("pairwise-auc")
        .reservoir(0)
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");
    assert_eq!(e.exit_code(), 2);

    // ...and one larger than the model cache would outlive its models
    let e = RunSpec::new("urls")
        .scale(0.005)
        .learner("pairwise-auc")
        .cache(10)
        .reservoir(99)
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");
    assert_eq!(e.exit_code(), 2);

    // the cycle-synchronous batched driver averages pointwise learners only
    let e = RunSpec::new("urls")
        .scale(0.005)
        .backend(BackendChoice::BatchedNative)
        .learner("pairwise-auc")
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");

    let e = RunSpec::new("urls")
        .scale(0.005)
        .backend(BackendChoice::BatchedNative)
        .merge(MergeMode::Quorum)
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");

    // the reservoir cap only binds the pairwise objective: a pointwise
    // learner with reservoir = 0 builds fine
    RunSpec::new("urls").scale(0.005).reservoir(0).build().unwrap();

    // the valid combination builds, and the variant alias survives the INI
    // round trip (alias -> mu + pairwise-auc learner)
    RunSpec::new("urls")
        .scale(0.005)
        .learner("pairwise-auc")
        .merge(MergeMode::Quorum)
        .reservoir(4)
        .build()
        .unwrap();
    let spec = RunSpec::from_ini(
        "[experiment]\ndataset = urls\nscale = 0.005\nvariant = pairwise-auc\n",
    )
    .unwrap();
    assert_eq!(spec.experiment.variant, Variant::Mu);
    assert_eq!(spec.experiment.learner_name, "pairwise-auc");
    let round = RunSpec::from_ini(&spec.to_ini()).unwrap();
    assert_eq!(round, spec, "\n{}", spec.to_ini());
}

/// Topology validation matrix (DESIGN.md §16): every rejection is a typed
/// error with its distinct exit code, raised at build time — never a panic
/// inside a running simulation.
#[test]
fn rejects_invalid_topology_combinations_with_typed_errors() {
    // an unparseable spec fails in the builder itself
    let e = RunSpec::new("urls").topology("warp").unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");
    assert_eq!(e.exit_code(), 2);

    // MATCHING pairs the whole membership; a graph constraint would be
    // silently ignored
    let e = RunSpec::new("urls")
        .scale(0.005)
        .sampler(SamplerConfig::Matching)
        .topology("ring:2")
        .unwrap()
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");

    // the batched driver has no per-message peer sampling to constrain
    let e = RunSpec::new("urls")
        .scale(0.005)
        .backend(BackendChoice::BatchedNative)
        .topology("ring:2")
        .unwrap()
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");

    // a graph leaving nodes at degree 0 can never gossip everywhere
    let e = RunSpec::new("urls")
        .scale(0.005)
        .topology("graph-inline:0-1")
        .unwrap()
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "config", "{e}");
    assert_eq!(e.exit_code(), 2);

    // edge-level failure events need a graph to mutate...
    let e = RunSpec::new("urls")
        .scale(0.005)
        .cycles(200)
        .eval_peers(5)
        .builtin_scenario("link-storm")
        .unwrap()
        .build()
        .unwrap_err();
    assert_eq!(kind(&e), "scenario", "{e}");
    assert_eq!(e.exit_code(), 5);

    // ...and an explicitly listed edge must exist in that graph
    let text = "
[experiment]
dataset = urls
scale = 0.005
cycles = 20
topology = ring:1

[scenario]
name = cut-a-chord

[event.cut]
at = 2
action = edge_fail:0-5
";
    let e = RunSpec::from_ini(text).unwrap().build().unwrap_err();
    assert_eq!(kind(&e), "scenario", "{e}");
    assert_eq!(e.exit_code(), 5);

    // the valid combination builds: link-storm over a ring
    RunSpec::new("urls")
        .scale(0.005)
        .cycles(200)
        .eval_peers(5)
        .builtin_scenario("link-storm")
        .unwrap()
        .topology("ring:2")
        .unwrap()
        .build()
        .unwrap();
}

// ---------------------------------------------------------------------------
// observer streaming (Sim and Batched targets)

/// Sim target: the streamed eval points are exactly the returned curve,
/// cycle boundaries are strictly increasing within the horizon, scenario
/// mutations stream as they apply — and observation is passive (an observed
/// run equals an unobserved one bit for bit).
#[test]
fn observer_stream_matches_outcome_sim() {
    let spec = || {
        RunSpec::new("urls")
            .scale(0.005)
            .cycles(8)
            .eval_peers(5)
            .seed(3)
            .builtin_scenario("paper-fig3")
            .unwrap()
    };
    let mut rec = CurveRecorder::new();
    let observed = spec().build().unwrap().run(&mut rec).unwrap();
    let curve = &observed.run_result().unwrap().curve;

    let streamed = rec.eval_points();
    assert_eq!(streamed.len(), curve.points.len());
    for (s, p) in streamed.iter().zip(&curve.points) {
        assert_eq!(s.cycle, p.cycle);
        assert_eq!(s.err_mean, p.err_mean);
        assert_eq!(s.err_std, p.err_std);
        assert_eq!(s.messages_sent, p.messages_sent);
    }
    let cycles = rec.cycles();
    assert!(!cycles.is_empty());
    assert!(cycles.windows(2).all(|w| w[0] < w[1]), "{cycles:?}");
    assert!(*cycles.last().unwrap() <= 8);
    // paper-fig3 applies its baseline failure models as mutations at cycle 0
    assert!(!rec.mutations().is_empty());
    assert!(rec.mutations().iter().all(|(c, _)| *c <= 8));

    // passivity: unobserved run is identical
    let unobserved = spec().build().unwrap().run(&mut NullObserver).unwrap();
    let a: Vec<f64> = curve.points.iter().map(|p| p.err_mean).collect();
    let b: Vec<f64> = unobserved
        .run_result()
        .unwrap()
        .curve
        .points
        .iter()
        .map(|p| p.err_mean)
        .collect();
    assert_eq!(a, b, "observation must not perturb the run");
}

/// Batched target: one Cycle event per cycle, eval events == curve.
#[test]
fn observer_stream_matches_outcome_batched() {
    let mut rec = CurveRecorder::new();
    let outcome = RunSpec::new("urls")
        .scale(0.005)
        .cycles(6)
        .eval_peers(5)
        .backend(BackendChoice::BatchedNative)
        .build()
        .unwrap()
        .run(&mut rec)
        .unwrap();
    let curve = &outcome.run_result().unwrap().curve;
    assert_eq!(rec.cycles(), (1..=6).collect::<Vec<u64>>());
    let streamed = rec.eval_points();
    assert_eq!(streamed.len(), curve.points.len());
    for (s, p) in streamed.iter().zip(&curve.points) {
        assert_eq!(s.cycle, p.cycle);
        assert_eq!(s.err_mean, p.err_mean);
    }
}

// ---------------------------------------------------------------------------
// outcomes

/// The facade's sweep outcome equals the sweep the grid runner produces,
/// and the uniform accessors see every cell.
#[test]
fn sweep_outcome_exposes_cells_uniformly() {
    let axes = SweepAxes {
        variants: vec![Variant::Mu],
        failures: vec![false],
        threads: 2,
        ..Default::default()
    };
    let outcome = RunSpec::new("urls")
        .scale(0.01)
        .cycles(3)
        .seed(7)
        .eval_peers(5)
        .sweep(axes)
        .build()
        .unwrap()
        .run(&mut NullObserver)
        .unwrap();
    let cells = outcome.sweep_cells().unwrap();
    assert_eq!(cells.len(), 3, "one cell per registry dataset");
    assert_eq!(outcome.curves().len(), 3);
    assert!(outcome.curve().is_some());
    assert_eq!(
        outcome.messages_sent(),
        cells.iter().map(|c| c.stats.messages_sent).sum::<u64>()
    );
    assert!(outcome.bytes_sent() > 0);
    // per-cell seeds still follow the historical derivation
    assert_eq!(
        cells[0].seed,
        golf::experiments::sweep::cell_seed(7, "reuters", Variant::Mu, false, "none", "complete", 0)
    );
}

/// A session can be run repeatedly (e.g. to compare observers) and a
/// borrowed-dataset session runs against the caller's data.
#[test]
fn sessions_are_reusable_and_borrowable() {
    let ds = urls_like(11, Scale(0.005));
    let session = RunSpec::new("urls")
        .cycles(3)
        .eval_peers(5)
        .build_with(&ds)
        .unwrap();
    assert_eq!(session.data().unwrap().name, "urls");
    let a = session.run(&mut NullObserver).unwrap();
    let b = session.run(&mut NullObserver).unwrap();
    assert_eq!(
        a.run_result().unwrap().curve.final_error(),
        b.run_result().unwrap().curve.final_error()
    );
}
