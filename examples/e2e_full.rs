//! End-to-end driver: exercises the FULL three-layer stack on a real small
//! workload, proving the layers compose:
//!
//!   L3 rust api::Session (this binary, batched target)
//!     -> runtime/ (PJRT CPU client)
//!       -> artifacts/*.hlo.txt  (L2 JAX graphs, AOT-lowered)
//!         -> Pallas kernels     (L1, interpret-mode, inside the HLO)
//!
//! Workload: the Malicious-URLs-like dataset at 1000 nodes, P2PegasosMU,
//! 100 cycles — the paper's headline experiment shape — run twice through
//! one `RunSpec` diff: once on the native backend and once through PJRT,
//! with the loss curves compared and throughput reported.  Results are
//! recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example e2e_full

use golf::api::{NullObserver, RunSpec};
use golf::config::BackendChoice;
use golf::data::synthetic::{urls_like, Scale};
use golf::util::benchkit::Table;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dataset = urls_like(2026, Scale(0.1)); // 1000 nodes, 24k test rows
    let cycles = 100;
    println!(
        "e2e: {} — {} nodes, d={}, {} test rows, {} cycles, P2PegasosMU\n",
        dataset.name,
        dataset.n_train(),
        dataset.d(),
        dataset.n_test(),
        cycles
    );

    // one spec, two backends: the only diff between the runs
    let spec = |backend| RunSpec::new("urls").cycles(cycles).backend(backend);

    // --- native backend
    let t0 = Instant::now();
    let res_native = spec(BackendChoice::BatchedNative)
        .build_with(&dataset)?
        .run(&mut NullObserver)?
        .into_run()
        .expect("batched outcome");
    let dt_native = t0.elapsed();

    // --- PJRT backend (AOT artifacts)
    let t0 = Instant::now();
    let res_pjrt = spec(BackendChoice::BatchedPjrt)
        .build_with(&dataset)?
        .run(&mut NullObserver)?
        .into_run()
        .expect("batched outcome");
    let dt_pjrt = t0.elapsed();

    // --- loss curves side by side
    let mut t = Table::new(&["cycle", "err (native)", "err (pjrt)", "|diff|"]);
    let mut max_diff = 0.0f64;
    for (a, b) in res_native.curve.points.iter().zip(&res_pjrt.curve.points) {
        let diff = (a.err_mean - b.err_mean).abs();
        max_diff = max_diff.max(diff);
        t.row(&[
            a.cycle.to_string(),
            format!("{:.4}", a.err_mean),
            format!("{:.4}", b.err_mean),
            format!("{:.2e}", diff),
        ]);
    }
    t.print();

    let msgs = res_native.stats.messages_sent as f64;
    let upd = res_native.stats.updates_applied as f64;
    println!("\nthroughput:");
    println!(
        "  native: {:>8.0} updates/s  ({:.2}s total)",
        upd / dt_native.as_secs_f64(),
        dt_native.as_secs_f64()
    );
    println!(
        "  pjrt:   {:>8.0} updates/s  ({:.2}s total)",
        upd / dt_pjrt.as_secs_f64(),
        dt_pjrt.as_secs_f64()
    );
    println!("  {} messages total, final error {:.4} (native) / {:.4} (pjrt)",
        msgs, res_native.curve.final_error(), res_pjrt.curve.final_error());

    anyhow::ensure!(
        max_diff < 5e-3,
        "native and PJRT trajectories diverged: max diff {max_diff}"
    );
    anyhow::ensure!(
        res_native.curve.final_error() < 0.12,
        "did not converge: {}",
        res_native.curve.final_error()
    );
    println!("\ne2e OK: all three layers compose and agree (max curve diff {max_diff:.2e})");
    Ok(())
}
