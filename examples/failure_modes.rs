//! Failure robustness demo (paper Section VI-A(i), Fig. 1 lower row):
//! message drop (50%), extreme delay (uniform [Δ, 10Δ]), churn (lognormal
//! sessions, 90% online), and all three combined — every condition expressed
//! as a one-line scenario diff on a shared `golf::api::RunSpec`.
//!
//!     cargo run --release --example failure_modes

use golf::api::{GolfError, NullObserver, RunSpec};
use golf::data::synthetic::{urls_like, Scale};
use golf::scenario::{ChurnSpec, DelaySpec, Scenario};
use golf::util::benchkit::Table;

/// A baseline-only scenario touching exactly one failure axis.
fn condition(
    name: &str,
    drop: Option<f64>,
    delay: Option<DelaySpec>,
    churn: Option<ChurnSpec>,
) -> Scenario {
    let mut s = Scenario::empty(name);
    s.drop = drop;
    s.delay = delay;
    s.churn = churn;
    s
}

fn main() -> Result<(), GolfError> {
    // one dataset shared by all five conditions (the specs differ only in
    // their scenario; the protocol seed matches the generation seed)
    let dataset = urls_like(11, Scale(0.05)); // 500 nodes
    let base = || RunSpec::new("urls").scale(0.05).seed(11).cycles(400);

    let specs: Vec<(&str, RunSpec)> = vec![
        ("no failures", base()),
        (
            "drop 50%",
            base().scenario(condition("drop-half", Some(0.5), None, None)),
        ),
        (
            "delay U[Δ,10Δ]",
            base().scenario(condition(
                "slow-links",
                None,
                Some(DelaySpec::Uniform(1.0, 10.0)),
                None,
            )),
        ),
        (
            "churn 90% online",
            base().scenario(condition("churny", None, None, Some(ChurnSpec::Paper))),
        ),
        // all three at once is the paper's Fig. 3 setup — a library built-in
        ("all failures", base().builtin_scenario("paper-fig3")?),
    ];

    println!(
        "{}: {} nodes, d={}, {} test rows, 400 cycles\n",
        dataset.name,
        dataset.n_train(),
        dataset.d(),
        dataset.n_test()
    );
    let mut t = Table::new(&[
        "scenario", "err@10", "err@50", "final", "to 0.15", "dropped", "lost offline",
    ]);
    for (name, spec) in specs {
        let outcome = spec.build_with(&dataset)?.run(&mut NullObserver)?;
        let res = outcome.run_result().expect("sim outcome");
        let at = |cy: u64| {
            res.curve
                .points
                .iter()
                .filter(|p| p.cycle <= cy)
                .next_back()
                .map_or(f64::NAN, |p| p.err_mean)
        };
        t.row(&[
            name.to_string(),
            format!("{:.3}", at(10)),
            format!("{:.3}", at(50)),
            format!("{:.3}", res.curve.final_error()),
            res.curve
                .cycles_to_reach(0.15)
                .map_or("-".into(), |v| v.to_string()),
            res.stats.messages_dropped.to_string(),
            res.stats.messages_lost_offline.to_string(),
        ]);
    }
    t.print();
    println!("\n(the paper's headline robustness claim: even the all-failure run converges\n to the same error, just ~10x later — delay accounts for ~5x, drop for ~2x)");
    Ok(())
}
