//! Failure robustness demo (paper Section VI-A(i), Fig. 1 lower row):
//! message drop (50%), extreme delay (uniform [Δ, 10Δ]), churn (lognormal
//! sessions, 90% online), and all three combined.
//!
//!     cargo run --release --example failure_modes

use golf::data::synthetic::{urls_like, Scale};
use golf::gossip::protocol::{run, ProtocolConfig, RunResult};
use golf::sim::churn::ChurnConfig;
use golf::sim::network::DelayModel;
use golf::util::benchkit::Table;

fn main() {
    let dataset = urls_like(11, Scale(0.05)); // 500 nodes
    let cycles = 400;

    let base = || {
        let mut c = ProtocolConfig::paper_default(cycles);
        c.eval.n_peers = 100;
        c
    };

    let scenarios: Vec<(&str, ProtocolConfig)> = vec![
        ("no failures", base()),
        ("drop 50%", {
            let mut c = base();
            c.network.drop_prob = 0.5;
            c
        }),
        ("delay U[Δ,10Δ]", {
            let mut c = base();
            c.network.delay = DelayModel::Uniform { lo: c.delta, hi: 10 * c.delta };
            c
        }),
        ("churn 90% online", {
            let mut c = base();
            c.churn = Some(ChurnConfig::paper_default(c.delta));
            c
        }),
        ("all failures", base().with_extreme_failures()),
    ];

    let mut t = Table::new(&[
        "scenario", "err@10", "err@50", "final", "to 0.15", "dropped", "lost offline",
    ]);
    for (name, cfg) in scenarios {
        let res: RunResult = run(cfg, &dataset);
        let at = |cy: u64| {
            res.curve
                .points
                .iter()
                .filter(|p| p.cycle <= cy)
                .next_back()
                .map_or(f64::NAN, |p| p.err_mean)
        };
        t.row(&[
            name.to_string(),
            format!("{:.3}", at(10)),
            format!("{:.3}", at(50)),
            format!("{:.3}", res.curve.final_error()),
            res.curve
                .cycles_to_reach(0.15)
                .map_or("-".into(), |v| v.to_string()),
            res.stats.messages_dropped.to_string(),
            res.stats.messages_lost_offline.to_string(),
        ]);
    }
    t.print();
    println!("\n(the paper's headline robustness claim: even the all-failure run converges\n to the same error, just ~10x later — delay accounts for ~5x, drop for ~2x)");
}
