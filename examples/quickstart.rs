//! Quickstart: run gossip learning (P2PegasosMU) on a small synthetic
//! Malicious-URLs-like workload and print the convergence curve.
//!
//!     cargo run --release --example quickstart

use golf::data::synthetic::{urls_like, Scale};
use golf::gossip::protocol::{run, ProtocolConfig};

fn main() {
    // 1. A fully distributed dataset: one training example per network node.
    let dataset = urls_like(42, Scale(0.05)); // 500 nodes, d = 10
    println!(
        "dataset: {} — {} nodes, {} test examples, {} features",
        dataset.name,
        dataset.n_train(),
        dataset.n_test(),
        dataset.d()
    );

    // 2. Protocol configuration: paper defaults are P2PegasosMU with a
    //    10-deep model cache and NEWSCAST peer sampling.
    let mut cfg = ProtocolConfig::paper_default(200);
    cfg.eval.n_peers = 100;

    // 3. Run the simulation and inspect the error curve.
    let result = run(cfg, &dataset);
    println!("\ncycle   mean 0-1 error (over 100 sampled peers)");
    for p in &result.curve.points {
        println!("{:>5}   {:.4}  {}", p.cycle, p.err_mean, bar(p.err_mean));
    }
    println!(
        "\n{} messages sent total ({} bytes), {} model updates applied",
        result.stats.messages_sent, result.stats.bytes_sent, result.stats.updates_applied
    );
}

fn bar(err: f64) -> String {
    "#".repeat((err * 60.0).round() as usize)
}
