//! Quickstart: run gossip learning (P2PegasosMU) on a small synthetic
//! Malicious-URLs-like workload through the `golf::api` facade and print the
//! convergence curve as it streams.
//!
//!     cargo run --release --example quickstart

use golf::api::{CurveRecorder, GolfError, RunSpec};

fn main() -> Result<(), GolfError> {
    // 1. One validated spec: dataset selection, protocol parameters, and
    //    execution target in a single builder (paper defaults are
    //    P2PegasosMU with a 10-deep model cache and NEWSCAST sampling).
    let session = RunSpec::new("urls")
        .scale(0.05) // 500 nodes, d = 10 — one training example per node
        .cycles(200)
        .seed(42)
        .build()?;

    let ds = session.data().expect("a single-run session owns its dataset");
    println!(
        "dataset: {} — {} nodes, {} test examples, {} features",
        ds.name,
        ds.n_train(),
        ds.n_test(),
        ds.d()
    );

    // 2. Run it, capturing the typed progress stream (a CLI would pass
    //    ProgressObserver for live output instead).
    let mut recorder = CurveRecorder::new();
    let outcome = session.run(&mut recorder)?;

    // 3. The streamed eval points are exactly the returned curve.
    println!("\ncycle   mean 0-1 error (over 100 sampled peers)");
    for p in recorder.eval_points() {
        println!("{:>5}   {:.4}  {}", p.cycle, p.err_mean, bar(p.err_mean));
    }
    let stats = outcome.run_stats().expect("sim outcome carries run stats");
    println!(
        "\n{} messages sent total ({} bytes), {} model updates applied",
        stats.messages_sent,
        outcome.bytes_sent(),
        stats.updates_applied
    );
    Ok(())
}

fn bar(err: f64) -> String {
    "#".repeat((err * 60.0).round() as usize)
}
