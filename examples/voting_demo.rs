//! Local voting demo (Algorithm 4, Fig. 3): every node predicts from its own
//! model cache at zero communication cost.  Voting markedly improves the
//! no-merge RW variant and slightly improves MU.
//!
//!     cargo run --release --example voting_demo

use golf::data::synthetic::{spambase_like, Scale};
use golf::gossip::create_model::Variant;
use golf::gossip::protocol::{run, ProtocolConfig};
use golf::util::benchkit::Table;

fn main() {
    let dataset = spambase_like(3, Scale(0.5));
    let cycles = 200;
    println!(
        "spambase-like: {} nodes; cache size 10; predictions over 100 peers\n",
        dataset.n_train()
    );

    for variant in [Variant::Rw, Variant::Mu] {
        let mut cfg = ProtocolConfig::paper_default(cycles);
        cfg.variant = variant;
        cfg.eval.n_peers = 100;
        cfg.eval.voting = true;
        let res = run(cfg, &dataset);

        println!("p2pegasos-{}", variant.name());
        let mut t = Table::new(&["cycle", "freshest-model err", "voted err", "gain"]);
        for p in &res.curve.points {
            if ![1, 2, 5, 10, 20, 50, 100, 200].contains(&p.cycle) {
                continue;
            }
            let v = p.err_vote.unwrap();
            t.row(&[
                p.cycle.to_string(),
                format!("{:.4}", p.err_mean),
                format!("{:.4}", v),
                format!("{:+.4}", p.err_mean - v),
            ]);
        }
        t.print();
        println!();
    }
    println!("(paper Fig. 3: voting is \"for free\" — same message complexity — and helps\n most where merging is absent; early cycles may degrade slightly since cached\n models are staler than the freshest one)");
}
