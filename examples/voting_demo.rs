//! Local voting demo (Algorithm 4, Fig. 3): every node predicts from its own
//! model cache at zero communication cost.  Voting markedly improves the
//! no-merge RW variant and slightly improves MU.
//!
//!     cargo run --release --example voting_demo

use golf::api::{GolfError, NullObserver, RunSpec};
use golf::gossip::create_model::Variant;
use golf::util::benchkit::Table;

fn main() -> Result<(), GolfError> {
    println!("spambase-like network; cache size 10; predictions over 100 peers\n");

    for variant in [Variant::Rw, Variant::Mu] {
        let outcome = RunSpec::new("spambase")
            .scale(0.5) // 2070 mailboxes
            .seed(3)
            .cycles(200)
            .variant(variant)
            .voting(true)
            .build()?
            .run(&mut NullObserver)?;
        let res = outcome.run_result().expect("sim outcome");

        println!("p2pegasos-{}", variant.name());
        let mut t = Table::new(&["cycle", "freshest-model err", "voted err", "gain"]);
        for p in &res.curve.points {
            if ![1, 2, 5, 10, 20, 50, 100, 200].contains(&p.cycle) {
                continue;
            }
            let v = p.err_vote.unwrap();
            t.row(&[
                p.cycle.to_string(),
                format!("{:.4}", p.err_mean),
                format!("{:.4}", v),
                format!("{:+.4}", p.err_mean - v),
            ]);
        }
        t.print();
        println!();
    }
    println!("(paper Fig. 3: voting is \"for free\" — same message complexity — and helps\n most where merging is absent; early cycles may degrade slightly since cached\n models are staler than the freshest one)");
    Ok(())
}
