//! Spambase scenario: the paper's spam-filtering motivation — every mailbox
//! (node) holds one labeled message vector; gossip learning trains a shared
//! spam model with no raw data movement.  Compares RW / MU / UM variants
//! against the sequential Pegasos baseline.
//!
//!     cargo run --release --example spambase_gossip

use golf::baselines::sequential;
use golf::data::synthetic::{spambase_like, Scale};
use golf::gossip::create_model::Variant;
use golf::gossip::protocol::{run, ProtocolConfig};
use golf::learning::Learner;
use golf::util::benchkit::Table;

fn main() {
    let dataset = spambase_like(7, Scale(0.5)); // 2070 mailboxes
    let cycles = 300;
    println!(
        "spambase-like: {} nodes, d={}, {} test rows\n",
        dataset.n_train(),
        dataset.d(),
        dataset.n_test()
    );

    let learner = Learner::pegasos(1e-2);
    let mut curves = vec![{
        let mut c = sequential::curve(&dataset, &learner, cycles, 1);
        c.label = "sequential pegasos".into();
        c
    }];
    for variant in [Variant::Rw, Variant::Mu, Variant::Um] {
        let mut cfg = ProtocolConfig::paper_default(cycles);
        cfg.variant = variant;
        cfg.learner = learner;
        cfg.eval.n_peers = 100;
        let mut c = run(cfg, &dataset).curve;
        c.label = format!("p2pegasos-{}", variant.name());
        curves.push(c);
    }

    let mut t = Table::new(&["algorithm", "err@10", "err@100", "final", "cycles to 0.20"]);
    for c in &curves {
        let at = |cy: u64| {
            c.points
                .iter()
                .filter(|p| p.cycle <= cy)
                .next_back()
                .map_or(f64::NAN, |p| p.err_mean)
        };
        t.row(&[
            c.label.clone(),
            format!("{:.3}", at(10)),
            format!("{:.3}", at(100)),
            format!("{:.3}", c.final_error()),
            c.cycles_to_reach(0.20)
                .map_or("-".into(), |v| v.to_string()),
        ]);
    }
    t.print();
    println!("\n(model merging should dominate: mu/um reach low error orders of magnitude\n earlier than the single-model baselines — paper Fig. 1 middle column)");
}
