//! Spambase scenario: the paper's spam-filtering motivation — every mailbox
//! (node) holds one labeled message vector; gossip learning trains a shared
//! spam model with no raw data movement.  Compares RW / MU / UM variants
//! against the sequential Pegasos baseline; the gossip runs share one
//! pre-built dataset through `RunSpec::build_with`.
//!
//!     cargo run --release --example spambase_gossip

use golf::api::{GolfError, NullObserver, RunSpec};
use golf::baselines::sequential;
use golf::data::synthetic::{spambase_like, Scale};
use golf::gossip::create_model::Variant;
use golf::learning::Learner;
use golf::util::benchkit::Table;

fn main() -> Result<(), GolfError> {
    // one dataset shared by the baseline and all three gossip runs
    let dataset = spambase_like(7, Scale(0.5)); // 2070 mailboxes
    let cycles = 300;
    println!(
        "spambase-like: {} nodes, d={}, {} test rows\n",
        dataset.n_train(),
        dataset.d(),
        dataset.n_test()
    );

    let learner = Learner::pegasos(1e-2);
    let mut curves = vec![{
        let mut c = sequential::curve(&dataset, &learner, cycles, 1);
        c.label = "sequential pegasos".into();
        c
    }];
    for variant in [Variant::Rw, Variant::Mu, Variant::Um] {
        let outcome = RunSpec::new("spambase")
            .seed(7)
            .cycles(cycles)
            .variant(variant)
            .lambda(1e-2)
            .build_with(&dataset)?
            .run(&mut NullObserver)?;
        let mut c = outcome.into_run().expect("sim outcome").curve;
        c.label = format!("p2pegasos-{}", variant.name());
        curves.push(c);
    }

    let mut t = Table::new(&["algorithm", "err@10", "err@100", "final", "cycles to 0.20"]);
    for c in &curves {
        let at = |cy: u64| {
            c.points
                .iter()
                .filter(|p| p.cycle <= cy)
                .next_back()
                .map_or(f64::NAN, |p| p.err_mean)
        };
        t.row(&[
            c.label.clone(),
            format!("{:.3}", at(10)),
            format!("{:.3}", at(100)),
            format!("{:.3}", c.final_error()),
            c.cycles_to_reach(0.20)
                .map_or("-".into(), |v| v.to_string()),
        ]);
    }
    t.print();
    println!("\n(model merging should dominate: mu/um reach low error orders of magnitude\n earlier than the single-model baselines — paper Fig. 1 middle column)");
    Ok(())
}
