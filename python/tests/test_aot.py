# AOT path: every emitted artifact must be valid HLO text with the expected
# entry layout, and the manifest must round-trip.
import os
import re
import subprocess
import sys

import pytest

from compile import aot


def test_artifact_list_unique_names():
    names = [n for n, *_ in aot.artifact_list(aot.QUICK)]
    assert len(names) == len(set(names))
    assert "pegasos_mu_b128_d16" in names


def test_bucket_tables_sane():
    for buckets in (aot.QUICK, aot.FULL):
        for key in ("D", "B", "N", "M"):
            assert buckets[key] == sorted(buckets[key])
            assert all(v > 0 for v in buckets[key])


def test_lower_one_op_produces_hlo(tmp_path):
    table = aot.op_table(b=8, d=4, n=8, m=2)
    fn, args, _ = table["pegasos_rw"]
    text = aot.to_hlo_text(fn, args)
    assert text.startswith("HloModule")
    # entry layout: 6 f32 inputs, tuple of (w', t') outputs
    m = re.search(r"entry_computation_layout=\{\(([^)]*)\)->", text)
    assert m and m.group(1).count("f32[8,4]") == 2


def test_emit_quick_set(tmp_path):
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--quick"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stderr
    manifest = (tmp_path / "manifest.tsv").read_text().strip().splitlines()
    rows = [l for l in manifest if not l.startswith("#")]
    assert len(rows) >= 10
    for row in rows:
        name, op, params, fname = row.split("\t")
        p = tmp_path / fname
        assert p.exists(), fname
        assert p.read_text().startswith("HloModule")
