# pytest: Pallas kernels vs the pure-jnp oracle (ref.py) -- the CORE
# correctness signal for L1.  hypothesis sweeps shapes and value regimes.
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (adaline_update, margins, merge, pegasos_update)
from compile.kernels import ref
from compile.kernels import common


def _rng(seed):
    return np.random.default_rng(seed)


def _batch(rng, b, d, scale=1.0):
    w = jnp.array(rng.normal(size=(b, d), scale=scale), jnp.float32)
    x = jnp.array(rng.normal(size=(b, d), scale=scale), jnp.float32)
    y = jnp.array(rng.choice([-1.0, 1.0], b), jnp.float32)
    t = jnp.array(rng.integers(1, 1000, b), jnp.float32)
    mask = jnp.array(rng.choice([0.0, 1.0], b), jnp.float32)
    return w, x, y, t, mask


shapes = st.tuples(st.integers(1, 33), st.integers(1, 70))


@settings(max_examples=30, deadline=None)
@given(shapes=shapes, seed=st.integers(0, 2**31 - 1),
       lam=st.sampled_from([1e-4, 1e-3, 1e-2, 0.1]))
def test_pegasos_matches_ref(shapes, seed, lam):
    b, d = shapes
    w, x, y, t, mask = _batch(_rng(seed), b, d)
    lamv = jnp.full((b,), lam, jnp.float32)
    ow, ot = pegasos_update(w, x, y, t, lamv, mask)
    rw, rt = ref.pegasos_update_ref(w, x, y, t, lamv, mask)
    np.testing.assert_allclose(ow, rw, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(ot, rt)


@settings(max_examples=30, deadline=None)
@given(shapes=shapes, seed=st.integers(0, 2**31 - 1),
       eta=st.sampled_from([1e-4, 1e-2, 0.5]))
def test_adaline_matches_ref(shapes, seed, eta):
    b, d = shapes
    w, x, y, t, mask = _batch(_rng(seed), b, d)
    etav = jnp.full((b,), eta, jnp.float32)
    ow, ot = adaline_update(w, x, y, t, etav, mask)
    rw, rt = ref.adaline_update_ref(w, x, y, t, etav, mask)
    np.testing.assert_allclose(ow, rw, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(ot, rt)


@settings(max_examples=30, deadline=None)
@given(shapes=shapes, seed=st.integers(0, 2**31 - 1),
       lam=st.sampled_from([1e-3, 1e-2, 0.1]))
def test_logreg_matches_ref(shapes, seed, lam):
    from compile.kernels import logreg_update
    b, d = shapes
    w, x, y, t, mask = _batch(_rng(seed), b, d)
    lamv = jnp.full((b,), lam, jnp.float32)
    ow, ot = logreg_update(w, x, y, t, lamv, mask)
    rw, rt = ref.logreg_update_ref(w, x, y, t, lamv, mask)
    np.testing.assert_allclose(ow, rw, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(ot, rt)


def test_logreg_probability_moves_toward_label():
    from compile.kernels import logreg_update
    w = jnp.zeros((1, 4), jnp.float32)
    x = jnp.ones((1, 4), jnp.float32)
    y = jnp.ones((1,), jnp.float32)
    t = jnp.zeros((1,), jnp.float32)
    lam = jnp.full((1,), 0.1, jnp.float32)
    one = jnp.ones((1,), jnp.float32)
    for _ in range(50):
        w, t = logreg_update(w, x, y, t, lam, one)
    p = 1.0 / (1.0 + np.exp(-float(jnp.sum(w * x))))
    assert p > 0.8, p


@settings(max_examples=20, deadline=None)
@given(shapes=shapes, seed=st.integers(0, 2**31 - 1))
def test_merge_matches_ref(shapes, seed):
    b, d = shapes
    rng = _rng(seed)
    w1, w2 = (jnp.array(rng.normal(size=(b, d)), jnp.float32) for _ in "ab")
    t1 = jnp.array(rng.integers(0, 100, b), jnp.float32)
    t2 = jnp.array(rng.integers(0, 100, b), jnp.float32)
    ow, ot = merge(w1, t1, w2, t2)
    rw, rt = ref.merge_ref(w1, t1, w2, t2)
    np.testing.assert_array_equal(ow, rw)
    np.testing.assert_array_equal(ot, rt)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 50), m=st.integers(1, 20), d=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
def test_margins_matches_ref(n, m, d, seed):
    rng = _rng(seed)
    x = jnp.array(rng.normal(size=(n, d)), jnp.float32)
    w = jnp.array(rng.normal(size=(m, d)), jnp.float32)
    np.testing.assert_allclose(margins(x, w), ref.margins_ref(x, w),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- edge cases

def test_pegasos_mask_zero_is_identity():
    w, x, y, t, _ = _batch(_rng(1), 8, 5)
    zero = jnp.zeros((8,), jnp.float32)
    lam = jnp.full((8,), 1e-2, jnp.float32)
    ow, ot = pegasos_update(w, x, y, t, lam, zero)
    np.testing.assert_array_equal(ow, w)
    np.testing.assert_array_equal(ot, t)


def test_pegasos_from_zero_model():
    """First update from the all-zeros init model (Algorithm 3 INITMODEL):
    margin is 0 < 1, so w_1 = eta_1 * y * x = y x / lambda."""
    d = 6
    x = jnp.array(_rng(2).normal(size=(1, d)), jnp.float32)
    w0 = jnp.zeros((1, d), jnp.float32)
    y = jnp.array([1.0], jnp.float32)
    lam = jnp.array([0.01], jnp.float32)
    ow, ot = pegasos_update(w0, x, y, jnp.zeros((1,), jnp.float32),
                            lam, jnp.ones((1,), jnp.float32))
    np.testing.assert_allclose(ow, x / 0.01, rtol=1e-5)
    assert float(ot[0]) == 1.0


def test_pegasos_correct_side_only_decays():
    """A confidently-correct example (margin >= 1) must only shrink w."""
    w = jnp.ones((1, 4), jnp.float32)
    x = jnp.ones((1, 4), jnp.float32)       # <w,x> = 4, y=1 -> margin 4 >= 1
    y = jnp.array([1.0], jnp.float32)
    t = jnp.array([9.0], jnp.float32)       # t'=10, eta=1/(lam*10)
    lam = jnp.array([0.1], jnp.float32)
    ow, _ = pegasos_update(w, x, y, t, lam, jnp.ones((1,), jnp.float32))
    np.testing.assert_allclose(ow, w * (1.0 - 1.0 / 10.0), rtol=1e-6)


def test_adaline_converges_on_one_example():
    """Repeated LMS steps on a single example drive the error to zero."""
    rng = _rng(3)
    x = jnp.array(rng.normal(size=(1, 8)), jnp.float32)
    y = jnp.array([1.0], jnp.float32)
    w = jnp.zeros((1, 8), jnp.float32)
    t = jnp.zeros((1,), jnp.float32)
    eta = jnp.array([0.05], jnp.float32)
    one = jnp.ones((1,), jnp.float32)
    for _ in range(200):
        w, t = adaline_update(w, x, y, t, eta, one)
    err = float(y[0] - jnp.sum(w * x))
    assert abs(err) < 1e-3
    assert float(t[0]) == 200.0


def test_margins_zero_dims_ok():
    x = jnp.zeros((4, 3), jnp.float32)
    w = jnp.zeros((2, 3), jnp.float32)
    np.testing.assert_array_equal(margins(x, w), jnp.zeros((4, 2)))


def test_row_block_respects_budget():
    for b, d in [(1, 1), (1024, 16), (1024, 10240), (7, 9947)]:
        bb = common.row_block(b, d)
        assert 1 <= bb <= max(1, b)
        assert bb * d * 4 * 3 <= common.VMEM_BLOCK_BUDGET or bb == 1


def test_explicit_block_sizes_agree():
    """Different legal tilings must not change the numbers."""
    w, x, y, t, mask = _batch(_rng(4), 32, 24)
    lam = jnp.full((32,), 1e-3, jnp.float32)
    a = pegasos_update(w, x, y, t, lam, mask, block_b=4)
    b = pegasos_update(w, x, y, t, lam, mask, block_b=32)
    np.testing.assert_allclose(a[0], b[0], rtol=1e-5, atol=1e-6)
    # different tilings reassociate the f32 contraction: tolerate ulp noise
    m1 = margins(x, w, block_n=8, block_m=8)
    m2 = margins(x, w, block_n=32, block_m=32)
    np.testing.assert_allclose(m1, m2, rtol=1e-4, atol=1e-5)
