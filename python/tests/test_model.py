# L2 graph semantics: the Algorithm-2 compositions and evaluation ops.
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _mk(seed, b, d):
    rng = np.random.default_rng(seed)
    f = lambda *s: jnp.array(rng.normal(size=s), jnp.float32)
    w1, w2, x = f(b, d), f(b, d), f(b, d)
    y = jnp.array(rng.choice([-1.0, 1.0], b), jnp.float32)
    t1 = jnp.array(rng.integers(1, 40, b), jnp.float32)
    t2 = jnp.array(rng.integers(1, 40, b), jnp.float32)
    lam = jnp.full((b,), 1e-3, jnp.float32)
    mask = jnp.ones((b,), jnp.float32)
    return w1, t1, w2, t2, x, y, lam, mask


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 17),
       d=st.integers(1, 40))
def test_mu_is_update_of_merge(seed, b, d):
    w1, t1, w2, t2, x, y, lam, mask = _mk(seed, b, d)
    ow, ot = model.pegasos_mu(w1, t1, w2, t2, x, y, lam, mask)
    wm, tm = ref.merge_ref(w1, t1, w2, t2)
    rw, rt = ref.pegasos_update_ref(wm, x, y, tm, lam, mask)
    np.testing.assert_allclose(ow, rw, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(ot, rt)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 17),
       d=st.integers(1, 40))
def test_um_is_merge_of_updates(seed, b, d):
    w1, t1, w2, t2, x, y, lam, mask = _mk(seed, b, d)
    ow, ot = model.pegasos_um(w1, t1, w2, t2, x, y, lam, mask)
    u1 = ref.pegasos_update_ref(w1, x, y, t1, lam, mask)
    u2 = ref.pegasos_update_ref(w2, x, y, t2, lam, mask)
    rw, rt = ref.merge_ref(u1[0], u1[1], u2[0], u2[1])
    np.testing.assert_allclose(ow, rw, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(ot, rt)


def test_adaline_um_equals_mu():
    """Section V-A: for Adaline's linear rule, update and merge commute
    (Eq. 8), so the UM and MU compositions yield identical models."""
    w1, t1, w2, t2, x, y, _, mask = _mk(7, 9, 21)
    eta = jnp.full((9,), 0.01, jnp.float32)
    mu = model.adaline_mu(w1, t1, w2, t2, x, y, eta, mask)
    um = model.adaline_um(w1, t1, w2, t2, x, y, eta, mask)
    np.testing.assert_allclose(mu[0], um[0], rtol=1e-5, atol=1e-6)


def test_error_counts_respect_padding():
    rng = np.random.default_rng(11)
    x = jnp.array(rng.normal(size=(10, 5)), jnp.float32)
    w = jnp.array(rng.normal(size=(3, 5)), jnp.float32)
    y = jnp.array(rng.choice([-1.0, 1.0], 10), jnp.float32)
    ypad = jnp.concatenate([y[:6], jnp.zeros((4,), jnp.float32)])
    full = model.eval_error_counts(x[:6], y[:6], w)[0]
    padded = model.eval_error_counts(x, ypad, w)[0]
    np.testing.assert_array_equal(full, padded)


def test_error_counts_zero_model_counts_all_wrong():
    """sign(0) <= 0 counts as misclassification for every test row, matching
    the untrained-model convention of the rust evaluator."""
    x = jnp.ones((7, 3), jnp.float32)
    y = jnp.ones((7,), jnp.float32)
    w = jnp.zeros((1, 3), jnp.float32)
    assert float(model.eval_error_counts(x, y, w)[0][0]) == 7.0


def test_similarity_identical_models_is_one():
    w = jnp.tile(jnp.array([[1.0, 2.0, 3.0]], jnp.float32), (5, 1))
    s = model.similarity_mean(w, jnp.ones((5,), jnp.float32))[0]
    np.testing.assert_allclose(float(s), 1.0, rtol=1e-5)


def test_similarity_mask_excludes_rows():
    rng = np.random.default_rng(5)
    w = jnp.array(rng.normal(size=(6, 8)), jnp.float32)
    mask = jnp.array([1, 1, 1, 0, 0, 0], jnp.float32)
    s = model.similarity_mean(w, mask)[0]
    wn = np.asarray(w[:3])
    wn = wn / np.linalg.norm(wn, axis=1, keepdims=True)
    g = wn @ wn.T
    exp = (g.sum() - np.trace(g)) / (3 * 2)
    np.testing.assert_allclose(float(s), exp, rtol=1e-4)


def test_opposite_models_similarity_negative():
    w = jnp.array([[1.0, 0.0], [-1.0, 0.0]], jnp.float32)
    s = model.similarity_mean(w, jnp.ones((2,), jnp.float32))[0]
    np.testing.assert_allclose(float(s), -1.0, rtol=1e-5)
