# Shared Pallas plumbing: block-size policy and spec builders.
#
# All kernels tile the batch dimension and keep the feature dimension whole
# inside a block (the paper's models are row-vectors; the update is a rowwise
# dot followed by an elementwise axpy, so there is no cross-row reuse to
# exploit).  Block sizes are chosen so one block's working set fits a TPU
# VMEM budget; on CPU (interpret=True) the same tiling simply bounds the
# working set per grid step.
from jax.experimental import pallas as pl

# Per-block VMEM budget (bytes).  A TPU core has ~16 MiB of VMEM; we keep a
# block's *inputs* under 4 MiB so double-buffering plus outputs fit easily.
VMEM_BLOCK_BUDGET = 4 * 1024 * 1024

# How many [block_b, D] f32 operands the row-tiled kernels keep live at once
# (w, x, and the output block).
_ROW_OPERANDS = 3


def row_block(b: int, d: int) -> int:
    """Pick the batch-tile size for a [B, D] row-wise kernel."""
    per_row = d * 4 * _ROW_OPERANDS
    bb = max(1, VMEM_BLOCK_BUDGET // per_row)
    # round down to a power of two, clamp to [1, min(B, 256)]
    p = 1
    while p * 2 <= bb:
        p *= 2
    return max(1, min(p, b, 256))


def mat_spec(block_b: int, d: int):
    """BlockSpec for a [B, D] operand tiled along rows only."""
    return pl.BlockSpec((block_b, d), lambda i: (i, 0))


def vec_spec(block_b: int):
    """BlockSpec for a [B] per-row scalar operand."""
    return pl.BlockSpec((block_b,), lambda i: (i,))
