# L1 Pallas kernel: batched Adaline (Widrow-Hoff LMS) update, paper Eq. (5).
#
# Same tiling as the Pegasos kernel; the update is unconditional
# (linear activation), which is what makes averaging strictly equivalent to
# voting for Adaline (paper Section V-A).
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _adaline_kernel(w_ref, x_ref, y_ref, t_ref, eta_ref, mask_ref,
                    ow_ref, ot_ref):
    w = w_ref[...]
    x = x_ref[...]
    y = y_ref[...]
    t = t_ref[...]
    eta = eta_ref[...]
    mask = mask_ref[...]

    err = y - jnp.sum(w * x, axis=1)             # y - <w, x>
    w_new = w + (eta * err)[:, None] * x

    m = mask[:, None]
    ow_ref[...] = m * w_new + (1.0 - m) * w
    ot_ref[...] = mask * (t + 1.0) + (1.0 - mask) * t


@functools.partial(jax.jit, static_argnames=("block_b",))
def adaline_update(w, x, y, t, eta, mask, *, block_b=None):
    """Batched Adaline update.  Shapes: w,x [B,D]; y,t,eta,mask [B]."""
    b, d = w.shape
    bb = block_b or common.row_block(b, d)
    grid = (pl.cdiv(b, bb),)
    return pl.pallas_call(
        _adaline_kernel,
        grid=grid,
        in_specs=[
            common.mat_spec(bb, d),
            common.mat_spec(bb, d),
            common.vec_spec(bb),
            common.vec_spec(bb),
            common.vec_spec(bb),
            common.vec_spec(bb),
        ],
        out_specs=(common.mat_spec(bb, d), common.vec_spec(bb)),
        out_shape=(
            jax.ShapeDtypeStruct((b, d), w.dtype),
            jax.ShapeDtypeStruct((b,), t.dtype),
        ),
        interpret=True,
    )(w, x, y, t, eta, mask)
