# L1 Pallas kernel: batched MERGE (Algorithm 3) -- elementwise average of two
# model populations, with the update counter taken as the pairwise max.
#
# This is the paper's core trick: averaging two linear models is (heuristically
# for Pegasos, exactly for Adaline) equivalent to weighted voting over the
# exponentially growing set of "virtual" models each carries (Section V).
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _merge_kernel(w1_ref, t1_ref, w2_ref, t2_ref, ow_ref, ot_ref):
    ow_ref[...] = (w1_ref[...] + w2_ref[...]) * 0.5
    ot_ref[...] = jnp.maximum(t1_ref[...], t2_ref[...])


@functools.partial(jax.jit, static_argnames=("block_b",))
def merge(w1, t1, w2, t2, *, block_b=None):
    """Pairwise-average two model batches.  w1,w2 [B,D]; t1,t2 [B]."""
    b, d = w1.shape
    bb = block_b or common.row_block(b, d)
    grid = (pl.cdiv(b, bb),)
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[
            common.mat_spec(bb, d),
            common.vec_spec(bb),
            common.mat_spec(bb, d),
            common.vec_spec(bb),
        ],
        out_specs=(common.mat_spec(bb, d), common.vec_spec(bb)),
        out_shape=(
            jax.ShapeDtypeStruct((b, d), w1.dtype),
            jax.ShapeDtypeStruct((b,), t1.dtype),
        ),
        interpret=True,
    )(w1, t1, w2, t2)
