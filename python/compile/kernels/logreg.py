# L1 Pallas kernel: batched L2-regularized online logistic regression.
#
# Beyond-paper extension (Section VII claims gossip learning generalizes to
# any online learner): same Pegasos-style 1/(lambda*t) step schedule, but the
# log-loss gradient
#     w' = (1 - eta*lam) w + eta * (y01 - sigmoid(<w, x>)) * x
# The rust learner (rust/src/learning/logreg.rs) mirrors this math.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _logreg_kernel(w_ref, x_ref, y_ref, t_ref, lam_ref, mask_ref,
                   ow_ref, ot_ref):
    w = w_ref[...]
    x = x_ref[...]
    y = y_ref[...]
    t = t_ref[...]
    lam = lam_ref[...]
    mask = mask_ref[...]

    t1 = t + 1.0
    eta = 1.0 / (lam * t1)
    z = jnp.sum(w * x, axis=1)
    p = 1.0 / (1.0 + jnp.exp(-z))            # sigmoid(<w, x>)
    y01 = (y + 1.0) * 0.5                    # {-1,1} -> {0,1}
    decay = (1.0 - eta * lam)[:, None] * w
    w_new = decay + (eta * (y01 - p))[:, None] * x

    m = mask[:, None]
    ow_ref[...] = m * w_new + (1.0 - m) * w
    ot_ref[...] = mask * t1 + (1.0 - mask) * t


@functools.partial(jax.jit, static_argnames=("block_b",))
def logreg_update(w, x, y, t, lam, mask, *, block_b=None):
    """Batched logistic-regression update.  Shapes as pegasos_update."""
    b, d = w.shape
    bb = block_b or common.row_block(b, d)
    grid = (pl.cdiv(b, bb),)
    return pl.pallas_call(
        _logreg_kernel,
        grid=grid,
        in_specs=[
            common.mat_spec(bb, d),
            common.mat_spec(bb, d),
            common.vec_spec(bb),
            common.vec_spec(bb),
            common.vec_spec(bb),
            common.vec_spec(bb),
        ],
        out_specs=(common.mat_spec(bb, d), common.vec_spec(bb)),
        out_shape=(
            jax.ShapeDtypeStruct((b, d), w.dtype),
            jax.ShapeDtypeStruct((b,), t.dtype),
        ),
        interpret=True,
    )(w, x, y, t, lam, mask)
