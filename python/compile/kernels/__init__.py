# L1: Pallas kernels for the gossip-learning hot path.
from .pegasos import pegasos_update
from .adaline import adaline_update
from .logreg import logreg_update
from .merge import merge
from .margins import margins

__all__ = ["pegasos_update", "adaline_update", "logreg_update", "merge",
           "margins"]
