# Pure-jnp correctness oracles for the Pallas kernels.
#
# Every kernel in this package has an exact reference implementation here;
# pytest (python/tests/) sweeps shapes/dtypes with hypothesis and asserts
# allclose between the pallas interpret-mode kernel and these functions.
# The rust NativeEngine mirrors the same math (rust/src/learning/), so this
# file is the single written-down semantics of the hot path.
import jax.numpy as jnp


def pegasos_update_ref(w, x, y, t, lam, mask):
    """Batched Pegasos (primal SVM SGD) update, Algorithm 3 of the paper.

    Args:
      w:    [B, D] current models.
      x:    [B, D] local training examples (one per row/node).
      y:    [B]    labels in {-1, +1}.
      t:    [B]    per-model update counts (float32 carrying integers).
      lam:  [B]    regularization parameter (broadcast per-row).
      mask: [B]    1.0 = apply update, 0.0 = pass through unchanged.

    Returns (w', t').
    """
    t1 = t + 1.0
    eta = 1.0 / (lam * t1)
    margin = y * jnp.sum(w * x, axis=-1)
    decay = (1.0 - eta * lam)[:, None] * w
    hinge_active = (margin < 1.0).astype(w.dtype)
    w_new = decay + (hinge_active * eta * y)[:, None] * x
    m = mask[:, None]
    return m * w_new + (1.0 - m) * w, mask * t1 + (1.0 - mask) * t


def adaline_update_ref(w, x, y, t, eta, mask):
    """Batched Adaline (Widrow-Hoff LMS) update, Eq. (5) of the paper."""
    err = y - jnp.sum(w * x, axis=-1)
    w_new = w + (eta * err)[:, None] * x
    m = mask[:, None]
    return m * w_new + (1.0 - m) * w, mask * (t + 1.0) + (1.0 - mask) * t


def logreg_update_ref(w, x, y, t, lam, mask):
    """Batched L2-regularized online logistic regression (extension)."""
    t1 = t + 1.0
    eta = 1.0 / (lam * t1)
    p = 1.0 / (1.0 + jnp.exp(-jnp.sum(w * x, axis=-1)))
    y01 = (y + 1.0) * 0.5
    w_new = (1.0 - eta * lam)[:, None] * w + (eta * (y01 - p))[:, None] * x
    m = mask[:, None]
    return m * w_new + (1.0 - m) * w, mask * t1 + (1.0 - mask) * t


def merge_ref(w1, t1, w2, t2):
    """Merge two model populations by averaging, Algorithm 3 MERGE."""
    return (w1 + w2) * 0.5, jnp.maximum(t1, t2)


def margins_ref(x, w):
    """[N, D] examples x [M, D] models -> [N, M] raw margins <w_j, x_i>.

    Used for test-set evaluation, weighted voting (Eq. 7) and as the
    building block of cosine model similarity (w @ w^T).
    """
    return x @ w.T
