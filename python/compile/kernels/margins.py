# L1 Pallas kernel: margins matmul  [N, D] examples x [M, D] models -> [N, M].
#
# Serves three consumers in the rust coordinator:
#   * test-set 0-1 error:      sign(margins) vs labels (paper Section VI-A(h))
#   * weighted voting (Eq. 7): sign(sum_j margins[:, j])
#   * model similarity:        margins(w, w) = w w^T, normalized to cosine.
#
# TPU shape: a 2-D grid of [block_n, block_m] output tiles; each grid step
# loads a [block_n, D] slab of examples and a [block_m, D] slab of models and
# contracts on the MXU.  D is kept whole per block: the paper's feature
# dimensions (10 .. 9947) fit VMEM alongside the row tiles.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _margins_kernel(x_ref, w_ref, o_ref):
    # [block_n, D] @ [D, block_m] on the MXU; f32 accumulation.
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...].T,
                         preferred_element_type=jnp.float32)


def _tile(n: int, d: int) -> int:
    per_row = d * 4 * 2  # x slab + w slab, f32
    bb = max(1, common.VMEM_BLOCK_BUDGET // per_row)
    p = 1
    while p * 2 <= bb:
        p *= 2
    return max(1, min(p, n, 128))


@functools.partial(jax.jit, static_argnames=("block_n", "block_m"))
def margins(x, w, *, block_n=None, block_m=None):
    """Raw margins <w_j, x_i>.  x [N,D], w [M,D] -> [N,M]."""
    n, d = x.shape
    m, _ = w.shape
    bn = block_n or _tile(n, d)
    bm = block_m or _tile(m, d)
    grid = (pl.cdiv(n, bn), pl.cdiv(m, bm))
    return pl.pallas_call(
        _margins_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x, w)
