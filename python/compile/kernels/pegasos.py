# L1 Pallas kernel: batched Pegasos update (Algorithm 3, UPDATEPEGASOS).
#
# One kernel invocation applies the Pegasos sub-gradient step to a whole
# batch of (model, local example) pairs at once -- this is the gossip
# simulator's hot path: every delivery tick, the rust coordinator batches all
# independent per-node updates into a single [B, D] call (see
# rust/src/engine/batcher.rs).
#
# TPU shape: rows tile VMEM as [block_b, D] blocks (BlockSpec below); the
# rowwise dot reduces on the VPU, the conditional hinge step is a masked
# elementwise axpy.  interpret=True everywhere in this image (CPU PJRT).
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _pegasos_kernel(w_ref, x_ref, y_ref, t_ref, lam_ref, mask_ref,
                    ow_ref, ot_ref):
    w = w_ref[...]
    x = x_ref[...]
    y = y_ref[...]
    t = t_ref[...]
    lam = lam_ref[...]
    mask = mask_ref[...]

    t1 = t + 1.0
    eta = 1.0 / (lam * t1)                       # eta_t = 1 / (lambda * t)
    margin = y * jnp.sum(w * x, axis=1)          # y <w, x>
    decay = (1.0 - eta * lam)[:, None] * w       # (1 - eta*lambda) w
    hinge = (margin < 1.0).astype(w.dtype)       # hinge-loss subgradient gate
    w_new = decay + (hinge * eta * y)[:, None] * x

    m = mask[:, None]
    ow_ref[...] = m * w_new + (1.0 - m) * w
    ot_ref[...] = mask * t1 + (1.0 - mask) * t


@functools.partial(jax.jit, static_argnames=("block_b",))
def pegasos_update(w, x, y, t, lam, mask, *, block_b=None):
    """Batched Pegasos update.  Shapes: w,x [B,D]; y,t,lam,mask [B]."""
    b, d = w.shape
    bb = block_b or common.row_block(b, d)
    grid = (pl.cdiv(b, bb),)
    return pl.pallas_call(
        _pegasos_kernel,
        grid=grid,
        in_specs=[
            common.mat_spec(bb, d),   # w
            common.mat_spec(bb, d),   # x
            common.vec_spec(bb),      # y
            common.vec_spec(bb),      # t
            common.vec_spec(bb),      # lam
            common.vec_spec(bb),      # mask
        ],
        out_specs=(common.mat_spec(bb, d), common.vec_spec(bb)),
        out_shape=(
            jax.ShapeDtypeStruct((b, d), w.dtype),
            jax.ShapeDtypeStruct((b,), t.dtype),
        ),
        interpret=True,
    )(w, x, y, t, lam, mask)
