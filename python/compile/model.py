# L2: the gossip-learning compute graphs, composed from the L1 kernels.
#
# Each function here is one "op" the rust coordinator executes through PJRT:
# a whole delivery tick's worth of independent per-node steps, batched into a
# single [B, D] computation (Algorithm 2's three createModel variants, plus
# evaluation).  aot.py lowers each op to HLO text per shape bucket; the rust
# runtime (rust/src/runtime/) loads + compiles the text and keeps python off
# the request path.
import jax.numpy as jnp

from .kernels import adaline_update, logreg_update, margins, merge, pegasos_update


# --------------------------------------------------------------------------
# Algorithm 2 variants, batched across nodes.
# m1 = incoming model (w1, t1); m2 = previously received model (w2, t2);
# (x, y) = the receiving node's single local example; mask gates padding rows.

def pegasos_rw(w1, x, y, t1, lam, mask):
    """CREATEMODELRW: update(m1)."""
    return pegasos_update(w1, x, y, t1, lam, mask)


def pegasos_mu(w1, t1, w2, t2, x, y, lam, mask):
    """CREATEMODELMU: update(merge(m1, m2))."""
    wm, tm = merge(w1, t1, w2, t2)
    return pegasos_update(wm, x, y, tm, lam, mask)


def pegasos_um(w1, t1, w2, t2, x, y, lam, mask):
    """CREATEMODELUM: merge(update(m1), update(m2)) -- both updates use the
    node's same local example (Section V-B discusses why this hurts
    independence relative to MU)."""
    u1w, u1t = pegasos_update(w1, x, y, t1, lam, mask)
    u2w, u2t = pegasos_update(w2, x, y, t2, lam, mask)
    return merge(u1w, u1t, u2w, u2t)


def adaline_rw(w1, x, y, t1, eta, mask):
    return adaline_update(w1, x, y, t1, eta, mask)


def adaline_mu(w1, t1, w2, t2, x, y, eta, mask):
    wm, tm = merge(w1, t1, w2, t2)
    return adaline_update(wm, x, y, tm, eta, mask)


def adaline_um(w1, t1, w2, t2, x, y, eta, mask):
    u1w, u1t = adaline_update(w1, x, y, t1, eta, mask)
    u2w, u2t = adaline_update(w2, x, y, t2, eta, mask)
    return merge(u1w, u1t, u2w, u2t)


def logreg_rw(w1, x, y, t1, lam, mask):
    return logreg_update(w1, x, y, t1, lam, mask)


def logreg_mu(w1, t1, w2, t2, x, y, lam, mask):
    wm, tm = merge(w1, t1, w2, t2)
    return logreg_update(wm, x, y, tm, lam, mask)


def logreg_um(w1, t1, w2, t2, x, y, lam, mask):
    u1w, u1t = logreg_update(w1, x, y, t1, lam, mask)
    u2w, u2t = logreg_update(w2, x, y, t2, lam, mask)
    return merge(u1w, u1t, u2w, u2t)


def merge_op(w1, t1, w2, t2):
    """Standalone MERGE (used by the coordinator's cache voting paths)."""
    return merge(w1, t1, w2, t2)


# --------------------------------------------------------------------------
# Evaluation graphs.

def eval_margins(x, w):
    """Raw margins for a test-set chunk against a model batch: [N, M]."""
    return (margins(x, w),)


def eval_error_counts(x, ylab, w):
    """Per-model misclassification counts over a test chunk.

    x [N, D], ylab [N] in {-1,+1} (0 rows = padding), w [M, D] -> [M] f32
    counts of misclassified rows under the repo-wide sign(0) = -1
    convention: predicted label is +1 iff <w, x> > 0, so a zero margin
    errs on positive rows only (matches rust eval/metrics.rs and the
    native backend's error_counts).  Padding rows (ylab == 0) contribute
    nothing.
    """
    mg = margins(x, w)                              # [N, M]
    pred = jnp.where(mg > 0.0, 1.0, -1.0)           # sign(0) = -1
    wrong = (pred != ylab[:, None]).astype(jnp.float32)
    valid = (ylab != 0.0).astype(jnp.float32)[:, None]
    return (jnp.sum(wrong * valid, axis=0),)


def similarity_mean(w, mask):
    """Mean pairwise cosine similarity over the masked model rows.

    w [M, D]; mask [M] with K = sum(mask) live rows.  Returns ([] f32,)
    the average of cos(w_i, w_j) over live i < j pairs (paper VI-A(h)).
    """
    norms = jnp.sqrt(jnp.sum(w * w, axis=1))
    safe = jnp.where(norms > 0.0, norms, 1.0)
    wn = w / safe[:, None] * mask[:, None]
    g = margins(wn, wn)                             # [M, M] gram = wn wn^T
    k = jnp.sum(mask)
    diag = jnp.sum(jnp.diagonal(g))
    total = jnp.sum(g) - diag
    pairs = jnp.maximum(k * (k - 1.0), 1.0)
    return (total / pairs,)
