# AOT compile path: lower every L2 op to HLO *text* per shape bucket and
# write artifacts/ + manifest.tsv for the rust runtime.
#
# HLO text (NOT .serialize()) is the interchange format: jax >= 0.5 emits
# HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
# version behind the published `xla` crate) rejects; the text parser
# reassigns ids and round-trips cleanly.  See /opt/xla-example/gen_hlo.py.
#
# Usage:  python -m compile.aot --out-dir ../artifacts [--quick]
import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

F32 = "float32"


def spec(*shape):
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def to_hlo_text(fn, args):
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Shape buckets.  The rust runtime picks the smallest bucket that fits and
# mask-pads (rust/src/runtime/artifacts.rs mirrors this table).
FULL = {
    "D": [16, 64, 128, 1024, 10240],
    "B": [128, 1024],
    "N": [1024],          # test-set chunk rows for eval ops
    "M": [16, 128],       # model count for eval ops
}
QUICK = {"D": [16, 64], "B": [128], "N": [256], "M": [16]}


def op_table(b, d, n, m):
    """op name -> (callable, example args).  All f32."""
    mat = spec(b, d)
    vec = spec(b)
    rw_args = (mat, mat, vec, vec, vec, vec)               # w,x,y,t,hp,mask
    mu_args = (mat, vec, mat, vec, mat, vec, vec, vec)     # w1,t1,w2,t2,x,y,hp,mask
    return {
        "pegasos_rw": (model.pegasos_rw, rw_args, dict(b=b, d=d)),
        "pegasos_mu": (model.pegasos_mu, mu_args, dict(b=b, d=d)),
        "pegasos_um": (model.pegasos_um, mu_args, dict(b=b, d=d)),
        "adaline_rw": (model.adaline_rw, rw_args, dict(b=b, d=d)),
        "adaline_mu": (model.adaline_mu, mu_args, dict(b=b, d=d)),
        "adaline_um": (model.adaline_um, mu_args, dict(b=b, d=d)),
        "logreg_rw": (model.logreg_rw, rw_args, dict(b=b, d=d)),
        "logreg_mu": (model.logreg_mu, mu_args, dict(b=b, d=d)),
        "logreg_um": (model.logreg_um, mu_args, dict(b=b, d=d)),
        "merge": (model.merge_op, (mat, vec, mat, vec), dict(b=b, d=d)),
        "eval_error_counts": (model.eval_error_counts,
                              (spec(n, d), spec(n), spec(m, d)),
                              dict(n=n, m=m, d=d)),
        "eval_margins": (model.eval_margins,
                         (spec(n, d), spec(m, d)), dict(n=n, m=m, d=d)),
        "similarity_mean": (model.similarity_mean,
                            (spec(m, d), spec(m)), dict(m=m, d=d)),
    }


def artifact_list(buckets):
    """Yield (name, op, params, fn, args) without duplicates."""
    seen = set()
    for d in buckets["D"]:
        for b in buckets["B"]:
            for n in buckets["N"]:
                for m in buckets["M"]:
                    for op, (fn, args, params) in op_table(b, d, n, m).items():
                        name = op + "".join(
                            f"_{k}{v}" for k, v in sorted(params.items()))
                        if name in seen:
                            continue
                        seen.add(name)
                        yield name, op, params, fn, args


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="compat: also write a copy of the first artifact here")
    ap.add_argument("--quick", action="store_true",
                    help="small bucket set for fast iteration")
    args = ap.parse_args()

    buckets = QUICK if args.quick else FULL
    os.makedirs(args.out_dir, exist_ok=True)
    manifest_rows = []
    first_path = None
    for name, op, params, fn, fargs in artifact_list(buckets):
        text = to_hlo_text(fn, fargs)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        if first_path is None:
            first_path = path
        pstr = ",".join(f"{k}={v}" for k, v in sorted(params.items()))
        manifest_rows.append(f"{name}\t{op}\t{pstr}\t{fname}")
        print(f"  {name}: {len(text)} chars", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("# name\top\tparams\tfile\n")
        f.write("\n".join(manifest_rows) + "\n")
    if args.out and first_path:
        import shutil
        shutil.copy(first_path, args.out)
    print(f"wrote {len(manifest_rows)} artifacts to {args.out_dir}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
